//! The real network front door: a dependency-free HTTP/1.1 transport
//! over the coordinator's [`Route`] table, instrumented from birth.
//!
//! Design:
//!
//! - **Transport.** One acceptor thread owns the [`TcpListener`] and
//!   hands accepted connections to a small worker pool over a bounded
//!   queue (back-pressure: a full queue answers `503` inline instead of
//!   stalling the accept loop). Each worker serves one connection at a
//!   time with keep-alive and request pipelining: requests are parsed
//!   out of a persistent per-connection buffer, so bytes of request
//!   `k+1` that arrive with request `k` are not lost. Read/write
//!   timeouts bound every blocking call; graceful shutdown sets a flag
//!   and wakes the blocking accept with a loopback connection.
//! - **Observability.** Every connection and request gets a monotone id
//!   carried into [`crate::obs::trace`] spans (`http.accept` around the
//!   connection, `http.request` around each dispatch — handler child
//!   spans such as `predict.flush` / `refresh` then nest by time), so a
//!   `/trace` dump decomposes a slow request end to end. Per-route
//!   latency histograms and status-class counters land in
//!   `/metrics?format=prom` as `http_request_latency_us{route=...}` /
//!   `http_requests_total{route=...,class=...}`; failures increment
//!   `http_errors_total{class=...}`; live connection and queue-depth
//!   gauges track saturation. Requests slower than `MSGP_SLOW_MS`
//!   milliseconds (or [`HttpConfig::slow_ms`]) emit one `WARN` line
//!   through the leveled logger.
//! - **Routes.** `GET` routes dispatch through
//!   [`Server::handle_path`] (query strings included, so
//!   `/metrics?format=prom`, `/shards?verbose=1` and `/trace?clear=1`
//!   work over the wire). `POST /predict` takes
//!   `{"points": [x0, x1, ...]}` (flat, or an array of per-point rows)
//!   and answers `{"mean": [...], "var": [...]}`; `POST /ingest` takes
//!   `{"xs": [...], "ys": [...], "flush": bool}` and answers
//!   `{"applied": k}`. Malformed input — oversized heads, bad
//!   content-length, early disconnects, unknown routes — is answered
//!   with 4xx/5xx and counted, never worker-fatal.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::{HttpErrClass, HttpMetrics};
use super::router::{metrics_format, MetricsFormat, Route};
use super::server::Server;
use crate::util::json::Json;

/// Monotone connection ids (process-wide, never 0).
static CONN_IDS: AtomicU64 = AtomicU64::new(0);
/// Monotone request ids (process-wide, never 0).
static REQ_IDS: AtomicU64 = AtomicU64::new(0);

/// Front-door tuning knobs. The defaults suit tests and modest
/// deployments; raise `workers`/`queue` for load.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Worker threads serving connections (>= 1).
    pub workers: usize,
    /// Per-read socket timeout; also the keep-alive idle bound.
    pub read_timeout: Duration,
    /// Per-write socket timeout.
    pub write_timeout: Duration,
    /// Cap on request line + headers, bytes (431 beyond).
    pub max_head_bytes: usize,
    /// Cap on a declared request body, bytes (413 beyond).
    pub max_body_bytes: usize,
    /// Requests served per connection before it is closed
    /// (0 = unlimited).
    pub max_requests_per_conn: usize,
    /// Accepted connections queued for workers before the acceptor
    /// answers 503 inline.
    pub queue: usize,
    /// Slow-request log threshold in milliseconds; `None` reads
    /// `MSGP_SLOW_MS` from the environment at bind time (unset/invalid
    /// = no slow logging).
    pub slow_ms: Option<u64>,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            workers: 4,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            max_head_bytes: 16 * 1024,
            max_body_bytes: 4 * 1024 * 1024,
            max_requests_per_conn: 0,
            queue: 256,
            slow_ms: None,
        }
    }
}

/// A bound, running HTTP front door over a [`Server`]. Dropping it (or
/// calling [`Self::shutdown`]) stops the acceptor, drains the workers,
/// and joins every thread.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    server: Arc<Server>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `server` on a worker pool.
    pub fn bind(server: Arc<Server>, addr: &str, cfg: HttpConfig) -> anyhow::Result<HttpServer> {
        let mut cfg = cfg;
        cfg.workers = cfg.workers.max(1);
        if cfg.slow_ms.is_none() {
            cfg.slow_ms = std::env::var("MSGP_SLOW_MS").ok().and_then(|v| v.parse().ok());
        }
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("http bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(cfg.queue.max(1));
        let shared_rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let rx = shared_rx.clone();
            let srv = server.clone();
            let wcfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("msgp-http-{i}"))
                    .spawn(move || worker_loop(rx, srv, wcfg))
                    // PANIC-OK: startup-time spawn; nothing serves yet.
                    .expect("spawn http worker"),
            );
        }

        let acc_server = server.clone();
        let acc_stop = stop.clone();
        let acc_cfg = cfg.clone();
        let acceptor = std::thread::Builder::new()
            .name("msgp-http-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    // ORDERING: Acquire pairs with the AcqRel swap in
                    // `shutdown_inner`, so the acceptor observes any
                    // state written before shutdown was requested.
                    if acc_stop.load(Ordering::Acquire) {
                        break; // the wake-up connection lands here too
                    }
                    let http = &acc_server.metrics.http;
                    match conn {
                        Ok(stream) => {
                            http.connections_total.inc();
                            http.queue_depth.fetch_add(1, Ordering::Relaxed);
                            match tx.try_send(stream) {
                                Ok(()) => {}
                                Err(TrySendError::Full(stream)) => {
                                    http.queue_depth.fetch_sub(1, Ordering::Relaxed);
                                    // Both the legacy aggregate class and
                                    // the per-cause refinement, so
                                    // pre-existing overload dashboards
                                    // keep working.
                                    http.error(HttpErrClass::Overload);
                                    http.error(HttpErrClass::QueueFull);
                                    let depth = http.queue_depth.get();
                                    reject_overloaded(stream, &acc_cfg, depth);
                                }
                                Err(TrySendError::Disconnected(_)) => {
                                    http.queue_depth.fetch_sub(1, Ordering::Relaxed);
                                    break;
                                }
                            }
                        }
                        Err(e) => {
                            crate::log_warn!("http accept error: {e}");
                        }
                    }
                }
                // Dropping `tx` here closes the queue; workers drain
                // whatever was accepted and then exit.
            })
            // PANIC-OK: startup-time spawn; nothing serves yet.
            .expect("spawn http acceptor");

        Ok(HttpServer { addr: local, stop, acceptor: Some(acceptor), workers, server })
    }

    /// The bound socket address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The coordinator behind this front door.
    pub fn server(&self) -> &Arc<Server> {
        &self.server
    }

    /// Graceful shutdown: stop accepting, drain queued connections,
    /// join every thread. (In-flight keep-alive connections close on
    /// their next idle read timeout at the latest.)
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // ORDERING: AcqRel — the Release half publishes pre-shutdown
        // writes to the acceptor's Acquire load; the Acquire half makes
        // the second caller of a racing double-shutdown see the first
        // caller's teardown before returning early.
        if self.stop.swap(true, Ordering::AcqRel) {
            return;
        }
        // Wake the blocking accept so the flag is observed.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Best-effort inline 503 from the acceptor thread when the worker
/// queue is full (bounded by the write timeout; errors ignored — the
/// client is being shed either way). The `Retry-After` hint scales
/// with the current queue depth so clients back off proportionally to
/// the backlog they would join.
fn reject_overloaded(stream: TcpStream, cfg: &HttpConfig, queue_depth: u64) {
    let mut stream = stream;
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let body = error_body("overloaded: worker queue full");
    let retry_after = retry_after_secs(queue_depth);
    let extra = [format!("Retry-After: {retry_after}")];
    let _ = write_response_with(&mut stream, 503, "application/json", &body, true, &extra);
}

/// Seconds a shed client should wait before retrying: 1s per 64 queued
/// connections, floor 1, capped at 30 so transient spikes never advise
/// minute-scale backoff.
fn retry_after_secs(queue_depth: u64) -> u64 {
    (queue_depth / 64 + 1).min(30)
}

fn worker_loop(rx: Arc<Mutex<Receiver<TcpStream>>>, server: Arc<Server>, cfg: HttpConfig) {
    // Each worker supervises its own per-connection loop: a panic while
    // serving one connection (handler bug or an armed `http.*`
    // failpoint) restarts the loop with backoff instead of silently
    // shrinking the pool. Repeated failures poison this worker — the
    // gauge flips `/healthz` to 503 so the operator sees it.
    let mut sup = crate::fault::Supervisor::new(
        crate::fault::SupervisorPolicy::default(),
        0x477b ^ std::process::id() as u64,
    );
    loop {
        let conn = rx.lock().unwrap_or_else(|e| e.into_inner()).recv();
        let Ok(stream) = conn else { break };
        let http = &server.metrics.http;
        http.queue_depth.fetch_sub(1, Ordering::Relaxed);
        http.connections_live.fetch_add(1, Ordering::Relaxed);
        let cid = CONN_IDS.fetch_add(1, Ordering::Relaxed) + 1;
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_connection(&server, &cfg, stream, cid)
        }));
        http.connections_live.fetch_sub(1, Ordering::Relaxed);
        if outcome.is_err() {
            server.metrics.record_worker_restart(super::metrics::WorkerKind::Http);
            match sup.on_failure() {
                crate::fault::Verdict::Restart(backoff) => {
                    crate::log_warn!(
                        "http worker panicked serving conn #{cid}; restarting after {:?}",
                        backoff
                    );
                    std::thread::sleep(backoff);
                }
                crate::fault::Verdict::Poison => {
                    server.metrics.worker_poisoned.fetch_add(1, Ordering::Relaxed);
                    crate::log_warn!("http worker poisoned after repeated panics; exiting");
                    break;
                }
            }
        }
    }
}

/// One parsed HTTP/1.1 request.
struct RawRequest {
    method: String,
    target: String,
    body: Vec<u8>,
    close: bool,
}

/// Outcome of trying to parse the next request off a connection.
enum ReadOutcome {
    /// A complete request (consumed from the buffer).
    Req(RawRequest),
    /// Clean close at a request boundary (EOF or idle timeout with an
    /// empty buffer) — not an error.
    Clean,
    /// Client hung up mid-request.
    Disconnect,
    /// Read timed out mid-request.
    Timeout,
    /// Request line + headers exceeded [`HttpConfig::max_head_bytes`].
    TooLargeHead,
    /// Declared body exceeds [`HttpConfig::max_body_bytes`].
    TooLargeBody,
    /// Unparseable request line / headers / content-length.
    Malformed,
}

fn serve_connection(server: &Server, cfg: &HttpConfig, mut stream: TcpStream, cid: u64) {
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    let _sp_conn = crate::span_arg!("http.accept", cid);
    crate::failpoint!("http.accept");
    let http = &server.metrics.http;
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut served = 0usize;
    loop {
        let req = match read_request(&mut stream, &mut buf, cfg) {
            ReadOutcome::Req(r) => r,
            ReadOutcome::Clean => break,
            ReadOutcome::Disconnect => {
                http.error(HttpErrClass::Disconnect);
                break;
            }
            ReadOutcome::Timeout => {
                http.error(HttpErrClass::Timeout);
                let body = error_body("read timed out mid-request");
                let _ = write_response(&mut stream, 408, "application/json", &body, true);
                break;
            }
            ReadOutcome::TooLargeHead => {
                http.error(HttpErrClass::TooLarge);
                let body = error_body("request head too large");
                let _ = write_response(&mut stream, 431, "application/json", &body, true);
                break;
            }
            ReadOutcome::TooLargeBody => {
                http.error(HttpErrClass::TooLarge);
                let body = error_body("request body too large");
                let _ = write_response(&mut stream, 413, "application/json", &body, true);
                break;
            }
            ReadOutcome::Malformed => {
                http.error(HttpErrClass::BadRequest);
                let body = error_body("malformed request");
                let _ = write_response(&mut stream, 400, "application/json", &body, true);
                break;
            }
        };
        served += 1;
        let req_id = REQ_IDS.fetch_add(1, Ordering::Relaxed) + 1;
        let t0 = Instant::now();
        let (status, ctype, body, ridx, extra) = {
            let _sp_req = crate::span_arg!("http.request", req_id);
            crate::failpoint!("http.dispatch");
            dispatch(server, &req)
        };
        let close = req.close
            || (cfg.max_requests_per_conn > 0 && served >= cfg.max_requests_per_conn);
        let write_ok =
            write_response_with(&mut stream, status, ctype, &body, close, &extra).is_ok();
        let elapsed = t0.elapsed();
        http.record(ridx, status, elapsed);
        if let Some(slow_ms) = cfg.slow_ms {
            if elapsed.as_millis() as u64 >= slow_ms {
                http.slow_total.inc();
                crate::log_warn!(
                    "slow http request #{req_id} {} {} -> {status} in {}ms (threshold {slow_ms}ms)",
                    req.method,
                    req.target,
                    elapsed.as_millis()
                );
            }
        }
        if !write_ok {
            http.error(HttpErrClass::Disconnect);
            break;
        }
        if close {
            break;
        }
    }
}

/// Parse one request out of `buf`, reading more bytes from `stream` as
/// needed. Leftover bytes (pipelined next requests) stay in `buf`.
fn read_request(stream: &mut TcpStream, buf: &mut Vec<u8>, cfg: &HttpConfig) -> ReadOutcome {
    let head_end = loop {
        if let Some(pos) = find_subslice(buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > cfg.max_head_bytes {
            return ReadOutcome::TooLargeHead;
        }
        match fill(stream, buf) {
            Fill::Bytes => {}
            Fill::Eof => {
                return if buf.is_empty() { ReadOutcome::Clean } else { ReadOutcome::Disconnect }
            }
            Fill::Timeout => {
                return if buf.is_empty() { ReadOutcome::Clean } else { ReadOutcome::Timeout }
            }
            Fill::Error => return ReadOutcome::Disconnect,
        }
    };
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(h) => h.to_string(),
        Err(_) => return ReadOutcome::Malformed,
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, target, version) =
        (parts.next().unwrap_or(""), parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1") {
        return ReadOutcome::Malformed;
    }
    let mut content_len = 0usize;
    let mut close = false;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once(':') else { return ReadOutcome::Malformed };
        let v = v.trim();
        if k.eq_ignore_ascii_case("content-length") {
            match v.parse::<usize>() {
                Ok(n) => content_len = n,
                Err(_) => return ReadOutcome::Malformed,
            }
        } else if k.eq_ignore_ascii_case("connection") {
            close = v.eq_ignore_ascii_case("close");
        } else if k.eq_ignore_ascii_case("transfer-encoding") {
            // Chunked bodies are not supported by this front door.
            return ReadOutcome::Malformed;
        }
    }
    if content_len > cfg.max_body_bytes {
        return ReadOutcome::TooLargeBody;
    }
    let total = head_end + 4 + content_len;
    while buf.len() < total {
        match fill(stream, buf) {
            Fill::Bytes => {}
            Fill::Eof | Fill::Error => return ReadOutcome::Disconnect,
            Fill::Timeout => return ReadOutcome::Timeout,
        }
    }
    let body = buf[head_end + 4..total].to_vec();
    let req = RawRequest {
        method: method.to_string(),
        target: target.to_string(),
        body,
        close,
    };
    buf.drain(..total);
    ReadOutcome::Req(req)
}

enum Fill {
    Bytes,
    Eof,
    Timeout,
    Error,
}

fn fill(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Fill {
    let mut tmp = [0u8; 4096];
    match stream.read(&mut tmp) {
        Ok(0) => Fill::Eof,
        Ok(n) => {
            buf.extend_from_slice(&tmp[..n]);
            Fill::Bytes
        }
        Err(e) if e.kind() == io::ErrorKind::WouldBlock || e.kind() == io::ErrorKind::TimedOut => {
            Fill::Timeout
        }
        Err(e) if e.kind() == io::ErrorKind::Interrupted => Fill::Bytes,
        Err(_) => Fill::Error,
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Route a parsed request to its handler. Returns
/// `(status, content-type, body, route index, extra header lines)`.
fn dispatch(server: &Server, req: &RawRequest) -> (u16, &'static str, String, usize, Vec<String>) {
    let route = Route::parse(&req.target);
    let ridx = HttpMetrics::route_index(route);
    let http = &server.metrics.http;
    let none: Vec<String> = Vec::new();
    match (req.method.as_str(), route) {
        ("POST", Some(Route::Predict)) => match handle_predict(server, &req.body) {
            Ok((body, extra)) => (200, "application/json", body, ridx, extra),
            Err((status, msg)) => {
                http.error(if status >= 500 {
                    HttpErrClass::Internal
                } else {
                    HttpErrClass::BadRequest
                });
                (status, "application/json", error_body(&msg), ridx, none)
            }
        },
        ("POST", Some(Route::Ingest)) => match handle_ingest(server, &req.body) {
            Ok(body) => (200, "application/json", body, ridx, none),
            Err((status, msg)) => {
                http.error(if status >= 500 {
                    HttpErrClass::Internal
                } else {
                    HttpErrClass::BadRequest
                });
                (status, "application/json", error_body(&msg), ridx, none)
            }
        },
        ("GET", Some(Route::Health)) => {
            let (healthy, body) = server.health();
            if healthy {
                (200, "application/json", body, ridx, none)
            } else {
                // Per-cause 503 accounting: the probe answered, but the
                // deployment is degraded (stale refresh, poisoned
                // worker, or still recovering).
                http.error(HttpErrClass::Degraded);
                (503, "application/json", body, ridx, none)
            }
        }
        ("GET", Some(Route::Failpoints)) => match server.handle_failpoints(&req.target) {
            Ok(body) => (200, "application/json", body, ridx, none),
            Err(msg) => {
                http.error(HttpErrClass::BadRequest);
                (400, "application/json", error_body(&msg), ridx, none)
            }
        },
        ("GET", Some(r)) => match server.handle_path(&req.target) {
            Some(text) => (200, get_content_type(r, &req.target), text, ridx, none),
            None if matches!(r, Route::Predict | Route::Ingest) => {
                http.error(HttpErrClass::BadRequest);
                (405, "application/json", error_body("use POST with a JSON body"), ridx, none)
            }
            None => (404, "application/json", error_body("no payload for this route"), ridx, none),
        },
        (_, None) => {
            http.error(HttpErrClass::UnknownRoute);
            (404, "application/json", error_body("unknown route"), ridx, none)
        }
        (_, Some(_)) => {
            http.error(HttpErrClass::BadRequest);
            (405, "application/json", error_body("method not allowed"), ridx, none)
        }
    }
}

fn get_content_type(route: Route, target: &str) -> &'static str {
    match route {
        Route::Health | Route::Trace | Route::Failpoints => "application/json",
        Route::Metrics if metrics_format(target) == MetricsFormat::Prometheus => {
            "text/plain; version=0.0.4"
        }
        _ => "text/plain; charset=utf-8",
    }
}

/// `POST /predict` body: `{"points": [x00, x01, ...]}` — a flat
/// row-major array of `k * dim` coordinates, or an array of `k`
/// per-point rows. Every point is submitted before any reply is
/// awaited, so one HTTP request becomes (at most) one batcher flush.
/// On cluster servers the answer comes from the local merged replica
/// view; when any point's owner node is down the response carries an
/// `X-Msgp-Staleness: <ms>` header bounding how old the replica data
/// backing it may be (the max across the batch).
fn handle_predict(server: &Server, body: &[u8]) -> Result<(String, Vec<String>), (u16, String)> {
    let doc = parse_json_body(body)?;
    let pts = doc
        .get("points")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| (400, "missing \"points\" array".to_string()))?;
    let dim = server.dim();
    let mut flat: Vec<f64> = Vec::new();
    for v in pts {
        match v {
            Json::Num(x) => flat.push(*x),
            Json::Arr(row) => {
                for c in row {
                    let x = c
                        .as_f64()
                        .ok_or_else(|| (400, "non-numeric coordinate".to_string()))?;
                    flat.push(x);
                }
            }
            _ => return Err((400, "points must be numbers or rows".to_string())),
        }
    }
    if flat.is_empty() || flat.len() % dim != 0 {
        return Err((400, format!("need a multiple of dim={dim} coordinates, got {}", flat.len())));
    }
    let n = flat.len() / dim;
    let mut means = Vec::with_capacity(n);
    let mut vars = Vec::with_capacity(n);
    if server.cluster().is_some() {
        // Cluster predictions answer inline from the local merged slot
        // (never over the network — a down peer cannot hang us), with
        // the staleness bound aggregated across the batch.
        let mut staleness: Option<u64> = None;
        for point in flat.chunks(dim) {
            let (p, stale) = server
                .cluster_predict(point)
                .ok_or_else(|| (500, "cluster predict unavailable".to_string()))?;
            if let Some(ms) = stale {
                staleness = Some(staleness.map_or(ms, |cur| cur.max(ms)));
            }
            means.push(Json::Num(p.mean));
            vars.push(Json::Num(p.var));
        }
        let body =
            Json::obj(vec![("mean", Json::Arr(means)), ("var", Json::Arr(vars))]).to_string();
        let extra = staleness.map(|ms| format!("X-Msgp-Staleness: {ms}")).into_iter().collect();
        return Ok((body, extra));
    }
    let mut pending = Vec::with_capacity(n);
    for point in flat.chunks(dim) {
        let rx = server.submit(point.to_vec()).map_err(|e| (500, e.to_string()))?;
        pending.push(rx);
    }
    for rx in pending {
        match rx.recv() {
            Ok(Ok(p)) => {
                means.push(Json::Num(p.mean));
                vars.push(Json::Num(p.var));
            }
            Ok(Err(e)) => return Err((500, e.to_string())),
            Err(_) => return Err((500, "server dropped reply".to_string())),
        }
    }
    Ok((Json::obj(vec![("mean", Json::Arr(means)), ("var", Json::Arr(vars))]).to_string(), Vec::new()))
}

/// `POST /ingest` body: `{"xs": [...], "ys": [...], "flush": bool}`.
/// Empty `xs`/`ys` with `"flush": true` forces a refresh + swap only.
fn handle_ingest(server: &Server, body: &[u8]) -> Result<String, (u16, String)> {
    let doc = parse_json_body(body)?;
    let xs = num_array(&doc, "xs")?;
    let ys = num_array(&doc, "ys")?;
    let flush = matches!(doc.get("flush"), Some(Json::Bool(true)));
    let applied = if xs.is_empty() && ys.is_empty() {
        if !flush {
            return Err((400, "empty ingest without \"flush\": true".to_string()));
        }
        0
    } else {
        server.ingest(xs, ys).map_err(|e| {
            // A recovering cluster node refuses ingest (accepted points
            // would be lost to catch-up adoption): that is 503 retry
            // territory, mirroring `/healthz`, not a caller error.
            if e.downcast_ref::<crate::cluster::Recovering>().is_some() {
                (503, e.to_string())
            } else {
                (400, e.to_string())
            }
        })?
    };
    if flush {
        server.flush_stream().map_err(|e| (400, e.to_string()))?;
    }
    Ok(Json::obj(vec![
        ("applied", Json::Num(applied as f64)),
        ("flushed", Json::Bool(flush)),
    ])
    .to_string())
}

fn parse_json_body(body: &[u8]) -> Result<Json, (u16, String)> {
    let text = std::str::from_utf8(body).map_err(|_| (400, "body is not UTF-8".to_string()))?;
    Json::parse(text).map_err(|e| (400, format!("body is not JSON: {e}")))
}

fn num_array(doc: &Json, key: &str) -> Result<Vec<f64>, (u16, String)> {
    match doc.get(key) {
        None => Ok(Vec::new()),
        Some(Json::Arr(items)) => items
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| (400, format!("non-numeric value in \"{key}\""))))
            .collect(),
        Some(_) => Err((400, format!("\"{key}\" must be an array"))),
    }
}

fn error_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    ctype: &str,
    body: &str,
    close: bool,
) -> io::Result<()> {
    write_response_with(stream, status, ctype, body, close, &[])
}

/// [`write_response`] with extra response header lines (no trailing
/// CRLF; e.g. `"Retry-After: 2"`).
fn write_response_with(
    stream: &mut TcpStream,
    status: u16,
    ctype: &str,
    body: &str,
    close: bool,
    extra_headers: &[String],
) -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        reason_phrase(status),
        body.len(),
        if close { "close" } else { "keep-alive" },
    );
    for h in extra_headers {
        head.push_str(h);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_search_finds_header_terminator() {
        assert_eq!(find_subslice(b"GET / HTTP/1.1\r\n\r\nrest", b"\r\n\r\n"), Some(14));
        assert_eq!(find_subslice(b"partial\r\n", b"\r\n\r\n"), None);
        assert_eq!(find_subslice(b"", b"\r\n\r\n"), None);
    }

    #[test]
    fn reason_phrases_cover_the_status_codes_in_use() {
        for status in [200u16, 400, 404, 405, 408, 413, 431, 500, 503] {
            assert_ne!(reason_phrase(status), "Error", "status {status}");
        }
        assert_eq!(reason_phrase(599), "Error");
    }

    #[test]
    fn retry_after_scales_with_queue_depth_and_caps() {
        assert_eq!(retry_after_secs(0), 1);
        assert_eq!(retry_after_secs(63), 1);
        assert_eq!(retry_after_secs(64), 2);
        assert_eq!(retry_after_secs(640), 11);
        assert_eq!(retry_after_secs(1_000_000), 30);
    }

    #[test]
    fn json_body_helpers_validate_shapes() {
        let doc = parse_json_body(br#"{"xs": [1.0, 2.5], "flush": true}"#).unwrap();
        assert_eq!(num_array(&doc, "xs").unwrap(), vec![1.0, 2.5]);
        assert_eq!(num_array(&doc, "ys").unwrap(), Vec::<f64>::new());
        assert!(num_array(&doc, "flush").is_err());
        assert!(parse_json_body(b"not json").is_err());
        assert!(parse_json_body(&[0xff, 0xfe]).is_err());
    }
}
