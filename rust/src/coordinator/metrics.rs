//! Serving metrics: a typed registry of named counters, gauges and a
//! log-scale latency histogram — all wait-free on the hot path, built
//! from the [`crate::obs::metrics`] primitives.
//!
//! Two renderings of the same registry:
//!
//! - [`Metrics::summary`] — the legacy one-line `key=value` format
//!   (the default `/metrics` payload; every pre-existing key is kept).
//! - [`Metrics::render_prometheus`] — Prometheus text exposition
//!   (`/metrics?format=prom`) with per-shard labels and real
//!   `_bucket`/`_sum`/`_count` series from the latency histogram.
//!
//! See `docs/METRICS.md` for the full metric-name reference.

use std::sync::atomic::Ordering;
use std::time::Duration;

use super::router::Route;
use crate::obs::metrics::{Counter, Gauge, HistogramSnapshot, LogHistogram, PromWriter};

/// Route label values for the per-route HTTP families, indexed by
/// [`HttpMetrics::route_index`]. The last slot aggregates unknown paths.
pub const HTTP_ROUTE_NAMES: [&str; 11] = [
    "predict",
    "ingest",
    "metrics",
    "models",
    "shards",
    "healthz",
    "trace",
    "failpoints",
    "cluster",
    "peers",
    "other",
];

/// `class` label values of `http_errors_total`, indexed by
/// [`HttpErrClass`] discriminants.
pub const HTTP_ERROR_CLASSES: [&str; 9] = [
    "bad_request",
    "too_large",
    "unknown_route",
    "disconnect",
    "timeout",
    "internal",
    "overload",
    "queue_full",
    "degraded",
];

/// `worker` label values of `worker_restarts_total`, indexed by
/// [`WorkerKind`] discriminants.
pub const WORKER_NAMES: [&str; 3] = ["ingest", "shard", "http"];

/// Supervised worker families (the `worker` label of
/// `worker_restarts_total`). Discriminants index [`WORKER_NAMES`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerKind {
    /// The unsharded background ingest/refresh thread.
    Ingest = 0,
    /// A sharded trainer worker (any shard; per-shard detail lives in
    /// [`ShardMetrics`]).
    Shard = 1,
    /// An HTTP front-door worker.
    Http = 2,
}

/// Front-door failure classes (the `class` label of
/// `http_errors_total`). Discriminants index [`HTTP_ERROR_CLASSES`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HttpErrClass {
    /// Unparseable request line/headers/body, bad content-length, or a
    /// method the route does not support.
    BadRequest = 0,
    /// Request line + headers or declared body exceeded the configured
    /// caps (431 / 413).
    TooLarge = 1,
    /// Path matched no [`Route`].
    UnknownRoute = 2,
    /// Client hung up mid-request.
    Disconnect = 3,
    /// Read timed out mid-request (408).
    Timeout = 4,
    /// Handler failure surfaced as a 500.
    Internal = 5,
    /// Accept queue full; connection refused with a 503.
    Overload = 6,
    /// Worker dispatch queue full; request shed with a 503 +
    /// `Retry-After` (the per-cause refinement of [`Self::Overload`]).
    QueueFull = 7,
    /// Served while the deployment was in degraded mode (stale
    /// snapshot under a refresh deadline or poisoned worker).
    Degraded = 8,
}

/// Per-route HTTP serving signals: one latency histogram plus
/// status-class counters.
#[derive(Debug, Default)]
pub struct HttpRoute {
    /// Transport-level request latency (first byte parsed → response
    /// written), microseconds.
    pub hist: LogHistogram,
    /// Responses with 2xx/3xx status.
    pub c2xx: Counter,
    /// Responses with 4xx status.
    pub c4xx: Counter,
    /// Responses with 5xx status.
    pub c5xx: Counter,
}

/// HTTP front-door metrics (see [`crate::coordinator::http`]). All
/// wait-free; one [`HttpRoute`] block per route label.
#[derive(Debug)]
pub struct HttpMetrics {
    /// Connections accepted since start.
    pub connections_total: Counter,
    /// Connections currently being served by a worker.
    pub connections_live: Gauge,
    /// Accepted connections queued for a worker (dispatch back-pressure).
    pub queue_depth: Gauge,
    /// HTTP requests answered (any status).
    pub requests_total: Counter,
    /// Requests that exceeded the `MSGP_SLOW_MS` slow-log threshold.
    pub slow_total: Counter,
    /// Per-route latency + status counters, indexed like
    /// [`HTTP_ROUTE_NAMES`].
    pub routes: [HttpRoute; 11],
    /// Failure counters, indexed like [`HTTP_ERROR_CLASSES`].
    pub errors: [Counter; 9],
}

impl Default for HttpMetrics {
    fn default() -> Self {
        HttpMetrics {
            connections_total: Counter::default(),
            connections_live: Gauge::default(),
            queue_depth: Gauge::default(),
            requests_total: Counter::default(),
            slow_total: Counter::default(),
            routes: std::array::from_fn(|_| HttpRoute::default()),
            errors: std::array::from_fn(|_| Counter::default()),
        }
    }
}

impl HttpMetrics {
    /// Index into [`Self::routes`] / [`HTTP_ROUTE_NAMES`] for a parsed
    /// route (`None` = unknown path → the `other` slot).
    pub fn route_index(route: Option<Route>) -> usize {
        match route {
            Some(Route::Predict) => 0,
            Some(Route::Ingest) => 1,
            Some(Route::Metrics) => 2,
            Some(Route::Models) => 3,
            Some(Route::Shards) => 4,
            Some(Route::Health) => 5,
            Some(Route::Trace) => 6,
            Some(Route::Failpoints) => 7,
            Some(Route::Cluster) => 8,
            Some(Route::Peers) => 9,
            None => 10,
        }
    }

    /// Record one answered request: total, per-route latency, and the
    /// status-class counter.
    pub fn record(&self, route_idx: usize, status: u16, d: Duration) {
        self.requests_total.inc();
        let r = &self.routes[route_idx.min(self.routes.len() - 1)];
        r.hist.record(d);
        match status {
            200..=399 => r.c2xx.inc(),
            400..=499 => r.c4xx.inc(),
            _ => r.c5xx.inc(),
        }
    }

    /// Count one front-door failure.
    pub fn error(&self, class: HttpErrClass) {
        self.errors[class as usize].inc();
    }

    /// Sum of every failure class (the summary-line aggregate).
    pub fn errors_total(&self) -> u64 {
        self.errors.iter().map(|c| c.get()).sum()
    }
}

/// Per-shard counters for sharded deployments (one entry per spatial
/// shard; see [`crate::shard`]). All wait-free atomics.
#[derive(Debug, Default)]
pub struct ShardMetrics {
    /// Owned observations absorbed by this shard's trainer.
    pub ingested: Counter,
    /// Halo copies absorbed (points owned by a neighbor but within this
    /// shard's overlap coverage).
    pub halo_ingested: Counter,
    /// Refresh + publish cycles completed by this shard.
    pub refreshes: Counter,
    /// Cumulative refresh CG iterations (mean + probe solves) on this
    /// shard — the per-shard view of the preconditioner win (the
    /// global `last_refresh_*` gauges are unsharded-only; S workers
    /// racing one gauge would make its reading meaningless).
    pub refresh_cg_iters: Counter,
    /// Wall-clock of this shard's most recent refresh, microseconds
    /// (single-writer: only the owning worker stores it) — the
    /// per-shard counterpart of the global `last_refresh_us` gauge, so
    /// the block-refresh speedup is observable in production on both
    /// server shapes.
    pub last_refresh_us: Gauge,
    /// Messages currently queued to this shard's worker (ingest
    /// back-pressure signal).
    pub queue_depth: Gauge,
    /// Prediction requests routed to this shard by the batcher.
    pub routed_predictions: Counter,
    /// Points currently held in this shard's reservoir (re-optimization
    /// snapshot pool; single-writer like `last_refresh_us`).
    pub reservoir_points: Gauge,
}

/// Per-peer replication counters for cluster deployments (one entry
/// per peer node, indexed by node id — the self slot stays zero; see
/// [`crate::cluster`]). All wait-free atomics.
#[derive(Debug, Default)]
pub struct PeerMetrics {
    /// `1` while the peer's heartbeat is fresh, `0` once failure
    /// detection declares it down (per-peer `degraded_mode` analog).
    pub up: Gauge,
    /// Frames waiting in this peer's bounded outbound queue.
    pub queue_depth: Gauge,
    /// Frames successfully written to this peer.
    pub sent: Counter,
    /// Send/connect failures against this peer (each triggers backoff
    /// and a reconnect-with-resync).
    pub send_errors: Counter,
    /// Connections (re-)established to this peer; the first session is
    /// counted too, so `reconnects - 1` is the retry tally.
    pub reconnects: Counter,
    /// Full-state snapshots shipped to this peer (connection resync and
    /// rejoin catch-up).
    pub full_syncs: Counter,
}

/// Serving metrics registry. All methods are thread-safe and wait-free.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests submitted.
    pub submitted: Counter,
    /// Requests completed (replies delivered).
    pub completed: Counter,
    /// Batches executed.
    pub batches: Counter,
    /// Sum of padded slots (for padding-overhead accounting).
    pub padded_slots: Counter,
    /// Batches executed on the PJRT backend.
    pub pjrt_batches: Counter,
    /// Batches executed on the native backend.
    pub native_batches: Counter,
    /// Streaming: observations absorbed by the ingest pipeline.
    pub ingested_points_total: Counter,
    /// Streaming: per-point trainer-admission rejections (grid
    /// expansion cap; also non-finite values when the front-door batch
    /// check in `Server::ingest` is bypassed — that check errors whole
    /// batches before they reach the trainer, so those points are not
    /// counted here).
    pub ingest_rejected_total: Counter,
    /// Streaming: ingest batches applied.
    pub ingest_batches: Counter,
    /// Streaming: cache refreshes + model swaps completed.
    pub refresh_count: Counter,
    /// Streaming: wall-clock of the most recent refresh, microseconds.
    pub last_refresh_us: Gauge,
    /// Streaming: trace-epoch timestamp (µs, see
    /// [`crate::obs::now_us`]) of the most recent refresh; `0` = no
    /// refresh yet. `/healthz` derives last-refresh *age* from this.
    pub last_refresh_at_us: Gauge,
    /// Streaming: stage-RHS wall-clock of the most recent refresh, µs
    /// (staging `W^T y` + probes through `S = K_UU^{1/2}` and `G`).
    /// Sourced from the same measurements that feed the tracer spans.
    pub last_refresh_stage_rhs_us: Gauge,
    /// Streaming: lockstep block-CG wall-clock of the most recent
    /// refresh, µs (the sequential-refresh path reports its whole solve
    /// loop here).
    pub last_refresh_block_solve_us: Gauge,
    /// Streaming: map-back wall-clock of the most recent refresh, µs
    /// (batched `S·x` + scaling + probe accumulation).
    pub last_refresh_map_back_us: Gauge,
    /// Streaming: wall-clock of the most recent model slot swap, µs.
    pub last_swap_us: Gauge,
    /// Streaming: CG iterations of the most recent refresh's mean
    /// solve (the preconditioner win is directly observable here).
    /// Unsharded servers only — sharded workers report per-shard
    /// cumulative counts in [`ShardMetrics::refresh_cg_iters`] instead
    /// of racing this gauge.
    pub last_refresh_mean_iters: Gauge,
    /// Streaming: total CG iterations across the most recent refresh's
    /// variance-probe solves (unsharded servers only, like
    /// [`Self::last_refresh_mean_iters`]).
    pub last_refresh_var_iters: Gauge,
    /// Streaming: cumulative refresh CG iterations (mean + probes)
    /// across all refreshes — the long-run iteration budget a
    /// preconditioner change moves.
    pub refresh_cg_iters_total: Counter,
    /// Streaming: refreshes that requested a preconditioner but had to
    /// degrade to unpreconditioned CG (misconfigured refresh inputs).
    pub precond_fallbacks: Counter,
    /// Streaming: thread count the in-tree pool had available during
    /// the most recent refresh (`1` = the batched FFT hot paths ran
    /// serially). Stored from `RefreshStats::threads` by the ingest
    /// loops; the live pool width is also exported as `pool_threads`.
    pub last_refresh_threads: Gauge,
    /// Streaming: hyperparameter re-optimizations completed.
    pub reopt_count: Counter,
    /// Streaming: points currently held in the trainer's reservoir
    /// (unsharded servers; sharded deployments report per-shard
    /// [`ShardMetrics::reservoir_points`]).
    pub reservoir_points: Gauge,
    /// Fault tolerance: supervised-worker restarts, indexed like
    /// [`WORKER_NAMES`] (`worker_restarts_total{worker=...}`).
    pub worker_restarts: [Counter; 3],
    /// Fault tolerance: workers currently poisoned (their supervisor
    /// gave up restarting; `/healthz` reports 503 while nonzero).
    pub worker_poisoned: Gauge,
    /// Fault tolerance: `1` while the server keeps serving the
    /// last-good snapshot because a refresh hit its deadline
    /// (`MSGP_REFRESH_DEADLINE_MS`) — predictions stay available but
    /// increasingly stale.
    pub degraded_mode: Gauge,
    /// Fault tolerance: `1` while startup checkpoint recovery is still
    /// rebuilding caches (predictions answer from the prior / the
    /// checkpointed snapshot).
    pub recovering: Gauge,
    /// Fault tolerance: checkpoints written (atomic tmp+fsync+rename).
    pub ckpt_writes_total: Counter,
    /// Fault tolerance: checkpoint writes that failed (I/O or injected
    /// `ckpt.*` failpoints) — the in-memory state keeps serving.
    pub ckpt_write_errors_total: Counter,
    /// Fault tolerance: wall-clock of the most recent checkpoint write,
    /// microseconds.
    pub ckpt_last_write_us: Gauge,
    /// Fault tolerance: checkpoints restored at startup.
    pub ckpt_restores_total: Counter,
    /// Fault tolerance: sequence number of the most recent checkpoint
    /// written or restored (monotone per process lifetime).
    pub ckpt_last_seq: Gauge,
    /// Cluster replication: frames received from peers (any kind).
    pub peer_frames_recv_total: Counter,
    /// Cluster replication: delta/full frames applied to replicas.
    pub peer_deltas_applied_total: Counter,
    /// Cluster replication: delta/full frames ignored by the epoch
    /// watermark (replays, reordered retries, stale grids).
    pub peer_deltas_ignored_total: Counter,
    /// Cluster replication: heartbeats received from peers.
    pub peer_heartbeats_total: Counter,
    /// Cluster replication: per-peer counters, indexed by node id
    /// (empty outside cluster mode; the self slot stays zero).
    pub peers: Vec<PeerMetrics>,
    /// Sharded serving: per-shard counters (empty on unsharded servers).
    pub shards: Vec<ShardMetrics>,
    /// HTTP front-door counters (zero until an
    /// [`crate::coordinator::http::HttpServer`] is bound).
    pub http: HttpMetrics,
    hist: LogHistogram,
}

impl Metrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fresh metrics with `n_shards` per-shard counter blocks.
    pub fn with_shards(n_shards: usize) -> Self {
        Metrics {
            shards: (0..n_shards).map(|_| ShardMetrics::default()).collect(),
            ..Default::default()
        }
    }

    /// Fresh metrics for a cluster node: `n_shards` per-shard blocks
    /// plus `n_peers` per-peer replication blocks (indexed by node id).
    pub fn with_cluster(n_shards: usize, n_peers: usize) -> Self {
        Metrics {
            shards: (0..n_shards).map(|_| ShardMetrics::default()).collect(),
            peers: (0..n_peers).map(|_| PeerMetrics::default()).collect(),
            ..Default::default()
        }
    }

    /// Record one request latency.
    pub fn record_latency(&self, d: Duration) {
        self.hist.record(d);
    }

    /// Approximate latency quantile (upper bucket edge), in
    /// microseconds. A quantile that lands in the top (overflow) bucket
    /// has no finite upper edge and saturates to `u64::MAX` — the same
    /// value the exhausted-scan path reports, so saturation is
    /// consistent.
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        self.hist.quantile_upper_us(q)
    }

    /// Record a completed refresh (count + latency + timestamp, one
    /// call so the three stay consistent).
    pub fn record_refresh(&self, d: Duration) {
        self.last_refresh_us.store(d.as_micros() as u64, Ordering::Relaxed);
        // `.max(1)` keeps 0 reserved for "never refreshed" even for a
        // refresh landing in the trace epoch's first microsecond.
        self.last_refresh_at_us.store(crate::obs::now_us().max(1), Ordering::Relaxed);
        self.refresh_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one refresh's CG iteration counts (mean solve + total
    /// across the variance probes) — the signal that makes the
    /// preconditioner choice observable at `/metrics`. Called by the
    /// unsharded ingest loop only; shard workers update their
    /// [`ShardMetrics::refresh_cg_iters`] and the cumulative total
    /// directly, leaving the `last_*` gauges single-writer.
    pub fn record_refresh_cg(&self, mean_iters: u64, var_iters: u64) {
        self.last_refresh_mean_iters.store(mean_iters, Ordering::Relaxed);
        self.last_refresh_var_iters.store(var_iters, Ordering::Relaxed);
        self.refresh_cg_iters_total.fetch_add(mean_iters + var_iters, Ordering::Relaxed);
    }

    /// Record how many pool threads the most recent refresh had
    /// available (from `RefreshStats::threads`). Every shard worker
    /// reports the same process-wide value, so the sharded race on this
    /// gauge is benign.
    pub fn record_refresh_threads(&self, threads: u64) {
        self.last_refresh_threads.store(threads, Ordering::Relaxed);
    }

    /// Record the most recent refresh's per-stage wall-clocks (µs) —
    /// the gauge-side mirror of the `refresh.stage_rhs` /
    /// `refresh.block_solve` / `refresh.map_back` tracer spans, sourced
    /// from the same measurements. Unsharded ingest loop only (the
    /// `last_*` single-writer rule).
    pub fn record_refresh_stages(&self, rhs_us: u64, solve_us: u64, map_us: u64) {
        self.last_refresh_stage_rhs_us.store(rhs_us, Ordering::Relaxed);
        self.last_refresh_block_solve_us.store(solve_us, Ordering::Relaxed);
        self.last_refresh_map_back_us.store(map_us, Ordering::Relaxed);
    }

    /// Count one supervised-worker restart.
    pub fn record_worker_restart(&self, kind: WorkerKind) {
        self.worker_restarts[kind as usize].inc();
    }

    /// Count one checkpoint write (latency + sequence in one call so
    /// the gauges stay consistent with the counter).
    pub fn record_ckpt_write(&self, seq: u64, d: Duration) {
        self.ckpt_writes_total.inc();
        self.ckpt_last_write_us.store(d.as_micros() as u64, Ordering::Relaxed);
        self.ckpt_last_seq.store(seq, Ordering::Relaxed);
    }

    /// Age of the most recent refresh in microseconds, or `None` if no
    /// refresh has completed yet.
    pub fn last_refresh_age_us(&self) -> Option<u64> {
        let at = self.last_refresh_at_us.get();
        if at == 0 {
            return None;
        }
        Some(crate::obs::now_us().saturating_sub(at))
    }

    /// Deepest per-shard worker queue (0 on unsharded servers) — the
    /// back-pressure signal `/healthz` reports.
    pub fn max_shard_queue_depth(&self) -> u64 {
        self.shards.iter().map(|s| s.queue_depth.get()).max().unwrap_or(0)
    }

    /// Total reservoir points across the deployment (the unsharded
    /// gauge plus every shard's).
    pub fn total_reservoir_points(&self) -> u64 {
        let sharded: u64 = self.shards.iter().map(|s| s.reservoir_points.get()).sum();
        self.reservoir_points.get() + sharded
    }

    /// One-line summary (the default `/metrics` endpoint payload).
    /// Sharded servers append one `shard[i] ...` clause per shard.
    /// `pool_threads` and `fft_parallel_panels_total` are read live from
    /// the in-tree parallel layer ([`crate::parallel`] /
    /// [`crate::linalg::fft`]) so they stay accurate even for refreshes
    /// driven outside the coordinator.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "submitted={} completed={} batches={} (pjrt={} native={}) padding={} p50<={}us p99<={}us \
             ingested_points_total={} ingest_rejected_total={} ingest_batches={} refresh_count={} last_refresh_us={} \
             last_refresh_mean_iters={} last_refresh_var_iters={} refresh_cg_iters_total={} precond_fallbacks={} reopt_count={} \
             pool_threads={} fft_parallel_panels_total={} last_refresh_threads={} \
             last_refresh_stage_rhs_us={} last_refresh_block_solve_us={} last_refresh_map_back_us={} \
             last_swap_us={} reservoir_points={}",
            self.submitted.get(),
            self.completed.get(),
            self.batches.get(),
            self.pjrt_batches.get(),
            self.native_batches.get(),
            self.padded_slots.get(),
            self.latency_quantile_us(0.5),
            self.latency_quantile_us(0.99),
            self.ingested_points_total.get(),
            self.ingest_rejected_total.get(),
            self.ingest_batches.get(),
            self.refresh_count.get(),
            self.last_refresh_us.get(),
            self.last_refresh_mean_iters.get(),
            self.last_refresh_var_iters.get(),
            self.refresh_cg_iters_total.get(),
            self.precond_fallbacks.get(),
            self.reopt_count.get(),
            crate::parallel::threads(),
            crate::linalg::fft::parallel_panels_total(),
            self.last_refresh_threads.get(),
            self.last_refresh_stage_rhs_us.get(),
            self.last_refresh_block_solve_us.get(),
            self.last_refresh_map_back_us.get(),
            self.last_swap_us.get(),
            self.reservoir_points.get(),
        );
        s.push_str(&format!(
            " http_connections_total={} http_connections={} http_queue_depth={} \
             http_requests_total={} http_errors_total={} http_slow_total={}",
            self.http.connections_total.get(),
            self.http.connections_live.get(),
            self.http.queue_depth.get(),
            self.http.requests_total.get(),
            self.http.errors_total(),
            self.http.slow_total.get(),
        ));
        s.push_str(&format!(
            " worker_restarts_total={} worker_poisoned={} degraded_mode={} recovering={} \
             ckpt_writes_total={} ckpt_write_errors_total={} ckpt_last_write_us={} \
             ckpt_restores_total={} ckpt_last_seq={}",
            self.worker_restarts.iter().map(|c| c.get()).sum::<u64>(),
            self.worker_poisoned.get(),
            self.degraded_mode.get(),
            self.recovering.get(),
            self.ckpt_writes_total.get(),
            self.ckpt_write_errors_total.get(),
            self.ckpt_last_write_us.get(),
            self.ckpt_restores_total.get(),
            self.ckpt_last_seq.get(),
        ));
        if !self.peers.is_empty() {
            s.push_str(&format!(
                " peer_frames_recv_total={} peer_deltas_applied_total={} \
                 peer_deltas_ignored_total={} peer_heartbeats_total={}",
                self.peer_frames_recv_total.get(),
                self.peer_deltas_applied_total.get(),
                self.peer_deltas_ignored_total.get(),
                self.peer_heartbeats_total.get(),
            ));
            for (i, p) in self.peers.iter().enumerate() {
                s.push_str(&format!(
                    " peer[{i}] up={} queue_depth={} sent={} send_errors={} reconnects={} \
                     full_syncs={}",
                    p.up.get(),
                    p.queue_depth.get(),
                    p.sent.get(),
                    p.send_errors.get(),
                    p.reconnects.get(),
                    p.full_syncs.get(),
                ));
            }
        }
        for (i, sh) in self.shards.iter().enumerate() {
            s.push_str(&format!(
                " shard[{i}] ingested={} halo={} refreshes={} cg_iters={} last_refresh_us={} \
                 queue_depth={} routed={} reservoir={}",
                sh.ingested.get(),
                sh.halo_ingested.get(),
                sh.refreshes.get(),
                sh.refresh_cg_iters.get(),
                sh.last_refresh_us.get(),
                sh.queue_depth.get(),
                sh.routed_predictions.get(),
                sh.reservoir_points.get(),
            ));
        }
        s
    }

    /// Prometheus text exposition (the `/metrics?format=prom` payload):
    /// every pre-existing metric name from [`Self::summary`], the
    /// latency histogram as cumulative `_bucket`/`_sum`/`_count`
    /// series, per-stage refresh gauges, and per-shard families labeled
    /// `{shard="i"}`.
    pub fn render_prometheus(&self) -> String {
        let mut w = PromWriter::new();
        let no_labels: Vec<(&str, String)> = Vec::new();
        let scalar = |w: &mut PromWriter, kind: &str, name: &str, help: &str, v: u64| {
            let samples = [(&no_labels[..], v)];
            match kind {
                "counter" => w.counter(name, help, &samples),
                _ => w.gauge(name, help, &samples),
            }
        };
        let counters: [(&str, &str, u64); 13] = [
            ("submitted", "Prediction requests submitted.", self.submitted.get()),
            ("completed", "Prediction requests completed.", self.completed.get()),
            ("batches", "Prediction batches executed.", self.batches.get()),
            ("pjrt_batches", "Batches executed on the PJRT backend.", self.pjrt_batches.get()),
            ("native_batches", "Batches executed natively.", self.native_batches.get()),
            ("padded_slots", "Padded batch slots (padding overhead).", self.padded_slots.get()),
            (
                "ingested_points_total",
                "Observations absorbed by the ingest pipeline.",
                self.ingested_points_total.get(),
            ),
            (
                "ingest_rejected_total",
                "Per-point trainer-admission rejections.",
                self.ingest_rejected_total.get(),
            ),
            ("ingest_batches", "Ingest batches applied.", self.ingest_batches.get()),
            ("refresh_count", "Refresh + model swap cycles.", self.refresh_count.get()),
            (
                "refresh_cg_iters_total",
                "Cumulative refresh CG iterations (mean + probes).",
                self.refresh_cg_iters_total.get(),
            ),
            (
                "precond_fallbacks",
                "Refreshes degraded to unpreconditioned CG.",
                self.precond_fallbacks.get(),
            ),
            ("reopt_count", "Hyperparameter re-optimizations.", self.reopt_count.get()),
        ];
        for (name, help, v) in counters {
            scalar(&mut w, "counter", name, help, v);
        }
        let gauges: [(&str, &str, u64); 12] = [
            ("last_refresh_us", "Most recent refresh wall-clock, us.", self.last_refresh_us.get()),
            (
                "last_refresh_at_us",
                "Trace-epoch timestamp of the most recent refresh, us (0 = never).",
                self.last_refresh_at_us.get(),
            ),
            (
                "last_refresh_stage_rhs_us",
                "Most recent refresh: stage-RHS wall-clock, us.",
                self.last_refresh_stage_rhs_us.get(),
            ),
            (
                "last_refresh_block_solve_us",
                "Most recent refresh: block-CG solve wall-clock, us.",
                self.last_refresh_block_solve_us.get(),
            ),
            (
                "last_refresh_map_back_us",
                "Most recent refresh: map-back wall-clock, us.",
                self.last_refresh_map_back_us.get(),
            ),
            ("last_swap_us", "Most recent model slot swap, us.", self.last_swap_us.get()),
            (
                "last_refresh_mean_iters",
                "CG iterations of the most recent refresh mean solve.",
                self.last_refresh_mean_iters.get(),
            ),
            (
                "last_refresh_var_iters",
                "CG iterations across the most recent refresh probe solves.",
                self.last_refresh_var_iters.get(),
            ),
            (
                "last_refresh_threads",
                "Pool threads available during the most recent refresh.",
                self.last_refresh_threads.get(),
            ),
            (
                "reservoir_points",
                "Points in the trainer reservoir (unsharded).",
                self.reservoir_points.get(),
            ),
            ("pool_threads", "Live in-tree pool width.", crate::parallel::threads() as u64),
            (
                "fft_parallel_panels_total",
                "FFT panel batches dispatched to the pool (process-wide).",
                crate::linalg::fft::parallel_panels_total(),
            ),
        ];
        for (name, help, v) in gauges {
            scalar(&mut w, "gauge", name, help, v);
        }
        // Fault-tolerance families (see docs/RELIABILITY.md).
        let worker_labels: Vec<Vec<(&str, String)>> =
            WORKER_NAMES.iter().map(|n| vec![("worker", n.to_string())]).collect();
        let worker_samples: Vec<(&[(&str, String)], u64)> = worker_labels
            .iter()
            .zip(self.worker_restarts.iter())
            .map(|(l, c)| (&l[..], c.get()))
            .collect();
        w.counter(
            "worker_restarts_total",
            "Supervised worker restarts, by worker family.",
            &worker_samples,
        );
        let fault_counters: [(&str, &str, u64); 3] = [
            (
                "ckpt_writes_total",
                "Checkpoints written (atomic tmp+fsync+rename).",
                self.ckpt_writes_total.get(),
            ),
            (
                "ckpt_write_errors_total",
                "Checkpoint writes that failed.",
                self.ckpt_write_errors_total.get(),
            ),
            (
                "ckpt_restores_total",
                "Checkpoints restored at startup.",
                self.ckpt_restores_total.get(),
            ),
        ];
        for (name, help, v) in fault_counters {
            scalar(&mut w, "counter", name, help, v);
        }
        let fault_gauges: [(&str, &str, u64); 5] = [
            ("worker_poisoned", "Workers whose supervisor gave up.", self.worker_poisoned.get()),
            (
                "degraded_mode",
                "1 while serving the last-good snapshot under a refresh deadline.",
                self.degraded_mode.get(),
            ),
            (
                "recovering",
                "1 while startup checkpoint recovery is rebuilding caches.",
                self.recovering.get(),
            ),
            (
                "ckpt_last_write_us",
                "Most recent checkpoint write wall-clock, us.",
                self.ckpt_last_write_us.get(),
            ),
            (
                "ckpt_last_seq",
                "Sequence number of the most recent checkpoint.",
                self.ckpt_last_seq.get(),
            ),
        ];
        for (name, help, v) in fault_gauges {
            scalar(&mut w, "gauge", name, help, v);
        }
        w.histogram(
            "request_latency_us",
            "Prediction request latency, us (log2 buckets).",
            &no_labels,
            &self.hist.snapshot(),
        );
        if !self.shards.is_empty() {
            let labels: Vec<Vec<(&str, String)>> =
                (0..self.shards.len()).map(|i| vec![("shard", i.to_string())]).collect();
            let family = |w: &mut PromWriter,
                          kind: &str,
                          name: &str,
                          help: &str,
                          get: &dyn Fn(&ShardMetrics) -> u64| {
                let samples: Vec<(&[(&str, String)], u64)> = self
                    .shards
                    .iter()
                    .zip(labels.iter())
                    .map(|(s, l)| (&l[..], get(s)))
                    .collect();
                match kind {
                    "counter" => w.counter(name, help, &samples),
                    _ => w.gauge(name, help, &samples),
                }
            };
            family(&mut w, "counter", "shard_ingested", "Owned points absorbed.", &|s| {
                s.ingested.get()
            });
            family(&mut w, "counter", "shard_halo_ingested", "Halo copies absorbed.", &|s| {
                s.halo_ingested.get()
            });
            family(&mut w, "counter", "shard_refreshes", "Refresh cycles completed.", &|s| {
                s.refreshes.get()
            });
            family(
                &mut w,
                "counter",
                "shard_refresh_cg_iters",
                "Cumulative refresh CG iterations.",
                &|s| s.refresh_cg_iters.get(),
            );
            family(
                &mut w,
                "gauge",
                "shard_last_refresh_us",
                "Most recent shard refresh wall-clock, us.",
                &|s| s.last_refresh_us.get(),
            );
            family(&mut w, "gauge", "shard_queue_depth", "Queued worker messages.", &|s| {
                s.queue_depth.get()
            });
            family(
                &mut w,
                "counter",
                "shard_routed_predictions",
                "Predictions routed to this shard.",
                &|s| s.routed_predictions.get(),
            );
            family(
                &mut w,
                "gauge",
                "shard_reservoir_points",
                "Points in this shard's reservoir.",
                &|s| s.reservoir_points.get(),
            );
        }
        if !self.peers.is_empty() {
            let cluster_counters: [(&str, &str, u64); 4] = [
                (
                    "peer_frames_recv_total",
                    "Replication frames received from peers.",
                    self.peer_frames_recv_total.get(),
                ),
                (
                    "peer_deltas_applied_total",
                    "Delta/full frames applied to replicas.",
                    self.peer_deltas_applied_total.get(),
                ),
                (
                    "peer_deltas_ignored_total",
                    "Delta/full frames ignored by the epoch watermark.",
                    self.peer_deltas_ignored_total.get(),
                ),
                (
                    "peer_heartbeats_total",
                    "Heartbeats received from peers.",
                    self.peer_heartbeats_total.get(),
                ),
            ];
            for (name, help, v) in cluster_counters {
                scalar(&mut w, "counter", name, help, v);
            }
            let labels: Vec<Vec<(&str, String)>> =
                (0..self.peers.len()).map(|i| vec![("peer", i.to_string())]).collect();
            let family = |w: &mut PromWriter,
                          kind: &str,
                          name: &str,
                          help: &str,
                          get: &dyn Fn(&PeerMetrics) -> u64| {
                let samples: Vec<(&[(&str, String)], u64)> = self
                    .peers
                    .iter()
                    .zip(labels.iter())
                    .map(|(p, l)| (&l[..], get(p)))
                    .collect();
                match kind {
                    "counter" => w.counter(name, help, &samples),
                    _ => w.gauge(name, help, &samples),
                }
            };
            family(&mut w, "gauge", "peer_up", "1 while the peer's heartbeat is fresh.", &|p| {
                p.up.get()
            });
            family(&mut w, "gauge", "peer_queue_depth", "Frames queued to this peer.", &|p| {
                p.queue_depth.get()
            });
            family(&mut w, "counter", "peer_sent_total", "Frames written to this peer.", &|p| {
                p.sent.get()
            });
            family(
                &mut w,
                "counter",
                "peer_send_errors_total",
                "Send/connect failures against this peer.",
                &|p| p.send_errors.get(),
            );
            family(
                &mut w,
                "counter",
                "peer_reconnects_total",
                "Connections established to this peer (first included).",
                &|p| p.reconnects.get(),
            );
            family(
                &mut w,
                "counter",
                "peer_full_syncs_total",
                "Full-state snapshots shipped to this peer.",
                &|p| p.full_syncs.get(),
            );
        }
        self.render_http(&mut w, &scalar);
        w.finish()
    }

    /// Append the `http_*` front-door families (always emitted, zeroed
    /// until an HTTP server is bound, so dashboards can pre-wire them).
    fn render_http(
        &self,
        w: &mut PromWriter,
        scalar: &dyn Fn(&mut PromWriter, &str, &str, &str, u64),
    ) {
        let h = &self.http;
        scalar(
            w,
            "counter",
            "http_connections_total",
            "Connections accepted by the front door.",
            h.connections_total.get(),
        );
        scalar(
            w,
            "gauge",
            "http_connections",
            "Connections currently being served.",
            h.connections_live.get(),
        );
        scalar(
            w,
            "gauge",
            "http_queue_depth",
            "Accepted connections awaiting a worker.",
            h.queue_depth.get(),
        );
        scalar(
            w,
            "counter",
            "http_slow_requests_total",
            "Requests over the MSGP_SLOW_MS threshold.",
            h.slow_total.get(),
        );
        let classes = ["2xx", "4xx", "5xx"];
        let mut req_labels: Vec<Vec<(&str, String)>> = Vec::new();
        let mut req_values: Vec<u64> = Vec::new();
        for (ri, r) in h.routes.iter().enumerate() {
            for (ci, cls) in classes.iter().enumerate() {
                req_labels.push(vec![
                    ("route", HTTP_ROUTE_NAMES[ri].to_string()),
                    ("class", cls.to_string()),
                ]);
                req_values.push(match ci {
                    0 => r.c2xx.get(),
                    1 => r.c4xx.get(),
                    _ => r.c5xx.get(),
                });
            }
        }
        let req_samples: Vec<(&[(&str, String)], u64)> =
            req_labels.iter().zip(req_values.iter()).map(|(l, &v)| (&l[..], v)).collect();
        w.counter(
            "http_requests_total",
            "HTTP requests answered, by route and status class.",
            &req_samples,
        );
        let err_labels: Vec<Vec<(&str, String)>> =
            HTTP_ERROR_CLASSES.iter().map(|c| vec![("class", c.to_string())]).collect();
        let err_samples: Vec<(&[(&str, String)], u64)> =
            err_labels.iter().zip(h.errors.iter()).map(|(l, c)| (&l[..], c.get())).collect();
        w.counter("http_errors_total", "Front-door failures, by class.", &err_samples);
        let snaps: Vec<HistogramSnapshot> = h.routes.iter().map(|r| r.hist.snapshot()).collect();
        let route_labels: Vec<Vec<(&str, String)>> =
            HTTP_ROUTE_NAMES.iter().map(|n| vec![("route", n.to_string())]).collect();
        let series: Vec<(&[(&str, String)], &HistogramSnapshot)> = route_labels
            .iter()
            .zip(snaps.iter())
            .filter(|(_, s)| s.count_from_buckets() > 0)
            .map(|(l, s)| (&l[..], s))
            .collect();
        w.histogram_family(
            "http_request_latency_us",
            "HTTP request latency by route, us (log2 buckets).",
            &series,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bracket_recorded_latencies() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_latency(Duration::from_micros(100));
        }
        for _ in 0..5 {
            m.record_latency(Duration::from_millis(10));
        }
        let p50 = m.latency_quantile_us(0.5);
        let p99 = m.latency_quantile_us(0.99);
        assert!(p50 >= 100 && p50 < 1000, "p50 {p50}");
        assert!(p99 >= 8_000, "p99 {p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.99), 0);
    }

    #[test]
    fn overflow_bucket_quantile_saturates_consistently() {
        // A latency in the top (overflow) bucket has no finite upper
        // edge: the quantile must report u64::MAX both when the scan
        // stops at the last bucket and when it exhausts the loop — not
        // a silent 2^63 us.
        let m = Metrics::new();
        m.record_latency(Duration::from_micros(u64::MAX));
        assert_eq!(m.latency_quantile_us(0.5), u64::MAX);
        assert_eq!(m.latency_quantile_us(1.0), u64::MAX);
    }

    #[test]
    fn per_shard_counters_appear_in_summary() {
        let m = Metrics::with_shards(2);
        m.shards[0].ingested.fetch_add(10, Ordering::Relaxed);
        m.shards[1].halo_ingested.fetch_add(3, Ordering::Relaxed);
        m.shards[1].queue_depth.fetch_add(5, Ordering::Relaxed);
        m.shards[0].refresh_cg_iters.fetch_add(42, Ordering::Relaxed);
        m.shards[0].last_refresh_us.store(777, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("shard[0] ingested=10"), "{s}");
        assert!(s.contains("halo=3"), "{s}");
        assert!(s.contains("queue_depth=5"), "{s}");
        assert!(s.contains("cg_iters=42"), "{s}");
        assert!(s.contains("last_refresh_us=777"), "{s}");
        // Unsharded metrics emit no shard clauses.
        assert!(!Metrics::new().summary().contains("shard[0]"));
    }

    #[test]
    fn streaming_counters_appear_in_summary() {
        let m = Metrics::new();
        m.ingested_points_total.fetch_add(123, Ordering::Relaxed);
        m.record_refresh(Duration::from_micros(456));
        let s = m.summary();
        assert!(s.contains("ingested_points_total=123"), "{s}");
        assert!(s.contains("refresh_count=1"), "{s}");
        assert!(s.contains("last_refresh_us=456"), "{s}");
    }

    #[test]
    fn refresh_cg_counters_accumulate_and_appear_in_summary() {
        let m = Metrics::new();
        m.record_refresh_cg(12, 80);
        m.record_refresh_cg(7, 40);
        assert_eq!(m.last_refresh_mean_iters.get(), 7);
        assert_eq!(m.last_refresh_var_iters.get(), 40);
        assert_eq!(m.refresh_cg_iters_total.get(), 139);
        m.precond_fallbacks.fetch_add(2, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("last_refresh_mean_iters=7"), "{s}");
        assert!(s.contains("last_refresh_var_iters=40"), "{s}");
        assert!(s.contains("refresh_cg_iters_total=139"), "{s}");
        assert!(s.contains("precond_fallbacks=2"), "{s}");
    }

    #[test]
    fn parallel_gauges_appear_in_summary() {
        let m = Metrics::new();
        m.record_refresh_threads(3);
        let s = m.summary();
        assert!(s.contains("last_refresh_threads=3"), "{s}");
        assert!(s.contains("fft_parallel_panels_total="), "{s}");
        // pool_threads reads the live pool width; concurrent tests may
        // reconfigure it between reads, so only pin its presence.
        assert!(s.contains("pool_threads="), "{s}");
    }

    #[test]
    fn stage_gauges_and_health_helpers() {
        let m = Metrics::new();
        assert_eq!(m.last_refresh_age_us(), None);
        m.record_refresh_stages(100, 800, 50);
        m.last_swap_us.store(9, Ordering::Relaxed);
        m.reservoir_points.store(321, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("last_refresh_stage_rhs_us=100"), "{s}");
        assert!(s.contains("last_refresh_block_solve_us=800"), "{s}");
        assert!(s.contains("last_refresh_map_back_us=50"), "{s}");
        assert!(s.contains("last_swap_us=9"), "{s}");
        assert!(s.contains("reservoir_points=321"), "{s}");
        m.record_refresh(Duration::from_micros(456));
        assert!(m.last_refresh_age_us().is_some());
        let sharded = Metrics::with_shards(2);
        sharded.shards[1].queue_depth.store(4, Ordering::Relaxed);
        sharded.shards[0].reservoir_points.store(10, Ordering::Relaxed);
        sharded.shards[1].reservoir_points.store(5, Ordering::Relaxed);
        assert_eq!(sharded.max_shard_queue_depth(), 4);
        assert_eq!(sharded.total_reservoir_points(), 15);
    }

    #[test]
    fn prometheus_exposes_every_preexisting_name() {
        let m = Metrics::with_shards(2);
        m.submitted.fetch_add(5, Ordering::Relaxed);
        m.record_latency(Duration::from_micros(200));
        m.shards[1].routed_predictions.fetch_add(2, Ordering::Relaxed);
        let text = m.render_prometheus();
        for name in [
            "submitted",
            "completed",
            "batches",
            "pjrt_batches",
            "native_batches",
            "padded_slots",
            "ingested_points_total",
            "ingest_rejected_total",
            "ingest_batches",
            "refresh_count",
            "last_refresh_us",
            "last_refresh_mean_iters",
            "last_refresh_var_iters",
            "refresh_cg_iters_total",
            "precond_fallbacks",
            "last_refresh_threads",
            "reopt_count",
            "pool_threads",
            "fft_parallel_panels_total",
            "last_refresh_stage_rhs_us",
            "last_refresh_block_solve_us",
            "last_refresh_map_back_us",
            "worker_restarts_total",
            "worker_poisoned",
            "degraded_mode",
            "recovering",
            "ckpt_writes_total",
            "ckpt_write_errors_total",
            "ckpt_restores_total",
            "ckpt_last_write_us",
            "ckpt_last_seq",
        ] {
            assert!(text.contains(&format!("# TYPE {name} ")), "missing {name}:\n{text}");
        }
        assert!(text.contains("submitted 5"), "{text}");
        assert!(text.contains("request_latency_us_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("request_latency_us_count 1"), "{text}");
        assert!(text.contains("shard_routed_predictions{shard=\"1\"} 2"), "{text}");
        assert!(text.contains("shard_queue_depth{shard=\"0\"} 0"), "{text}");
    }

    #[test]
    fn http_metrics_route_index_covers_every_route() {
        let routes = [
            (Some(Route::Predict), "predict"),
            (Some(Route::Ingest), "ingest"),
            (Some(Route::Metrics), "metrics"),
            (Some(Route::Models), "models"),
            (Some(Route::Shards), "shards"),
            (Some(Route::Health), "healthz"),
            (Some(Route::Trace), "trace"),
            (Some(Route::Failpoints), "failpoints"),
            (Some(Route::Cluster), "cluster"),
            (Some(Route::Peers), "peers"),
            (None, "other"),
        ];
        let mut seen = [false; 11];
        for (r, name) in routes {
            let i = HttpMetrics::route_index(r);
            assert_eq!(HTTP_ROUTE_NAMES[i], name);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s), "route indices not a bijection");
    }

    #[test]
    fn http_families_render_in_summary_and_prometheus() {
        let m = Metrics::new();
        let pi = HttpMetrics::route_index(Some(Route::Predict));
        m.http.connections_total.inc();
        m.http.record(pi, 200, Duration::from_micros(120));
        m.http.record(pi, 200, Duration::from_micros(90));
        m.http.record(pi, 400, Duration::from_micros(10));
        m.http.error(HttpErrClass::BadRequest);
        m.http.error(HttpErrClass::UnknownRoute);
        m.http.error(HttpErrClass::UnknownRoute);

        let s = m.summary();
        // Pre-existing keys stay first; http keys append before shards.
        assert!(s.starts_with("submitted=0 "), "{s}");
        assert!(s.contains("http_connections_total=1"), "{s}");
        assert!(s.contains("http_requests_total=3"), "{s}");
        assert!(s.contains("http_errors_total=3"), "{s}");

        let text = m.render_prometheus();
        assert!(text.contains("http_connections_total 1"), "{text}");
        assert!(
            text.contains("http_requests_total{route=\"predict\",class=\"2xx\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("http_requests_total{route=\"predict\",class=\"4xx\"} 1"),
            "{text}"
        );
        assert!(text.contains("http_errors_total{class=\"unknown_route\"} 2"), "{text}");
        assert!(text.contains("http_errors_total{class=\"timeout\"} 0"), "{text}");
        assert!(text.contains("http_errors_total{class=\"queue_full\"} 0"), "{text}");
        assert!(text.contains("http_errors_total{class=\"degraded\"} 0"), "{text}");
        assert!(
            text.contains("http_request_latency_us_bucket{route=\"predict\",le=\"+Inf\"} 3"),
            "{text}"
        );
        assert!(text.contains("http_request_latency_us_count{route=\"predict\"} 3"), "{text}");
        // Quiet routes are filtered out of the histogram family; the
        // header itself is always present.
        assert!(!text.contains("http_request_latency_us_count{route=\"trace\"}"), "{text}");
        assert_eq!(text.matches("# TYPE http_request_latency_us histogram").count(), 1);
    }

    #[test]
    fn fault_families_render_in_summary_and_prometheus() {
        let m = Metrics::new();
        m.record_worker_restart(WorkerKind::Ingest);
        m.record_worker_restart(WorkerKind::Ingest);
        m.record_worker_restart(WorkerKind::Http);
        m.worker_poisoned.store(1, Ordering::Relaxed);
        m.degraded_mode.store(1, Ordering::Relaxed);
        m.record_ckpt_write(41, Duration::from_micros(250));
        m.ckpt_restores_total.inc();

        let s = m.summary();
        assert!(s.contains("worker_restarts_total=3"), "{s}");
        assert!(s.contains("worker_poisoned=1"), "{s}");
        assert!(s.contains("degraded_mode=1"), "{s}");
        assert!(s.contains("recovering=0"), "{s}");
        assert!(s.contains("ckpt_writes_total=1"), "{s}");
        assert!(s.contains("ckpt_last_write_us=250"), "{s}");
        assert!(s.contains("ckpt_last_seq=41"), "{s}");
        assert!(s.contains("ckpt_restores_total=1"), "{s}");

        let text = m.render_prometheus();
        assert!(text.contains("worker_restarts_total{worker=\"ingest\"} 2"), "{text}");
        assert!(text.contains("worker_restarts_total{worker=\"http\"} 1"), "{text}");
        assert!(text.contains("worker_restarts_total{worker=\"shard\"} 0"), "{text}");
        assert!(text.contains("degraded_mode 1"), "{text}");
        assert!(text.contains("ckpt_last_seq 41"), "{text}");
    }

    #[test]
    fn peer_families_render_in_summary_and_prometheus() {
        let m = Metrics::with_cluster(4, 3);
        assert_eq!(m.shards.len(), 4);
        m.peers[1].up.store(1, Ordering::Relaxed);
        m.peers[1].sent.fetch_add(12, Ordering::Relaxed);
        m.peers[2].send_errors.fetch_add(3, Ordering::Relaxed);
        m.peers[2].reconnects.fetch_add(2, Ordering::Relaxed);
        m.peer_frames_recv_total.fetch_add(40, Ordering::Relaxed);
        m.peer_deltas_applied_total.fetch_add(30, Ordering::Relaxed);
        m.peer_deltas_ignored_total.fetch_add(5, Ordering::Relaxed);
        m.peer_heartbeats_total.fetch_add(9, Ordering::Relaxed);

        let s = m.summary();
        assert!(s.contains("peer_frames_recv_total=40"), "{s}");
        assert!(s.contains("peer_deltas_applied_total=30"), "{s}");
        assert!(s.contains("peer_deltas_ignored_total=5"), "{s}");
        assert!(s.contains("peer_heartbeats_total=9"), "{s}");
        assert!(s.contains("peer[1] up=1"), "{s}");
        assert!(s.contains("send_errors=3"), "{s}");
        // Non-cluster metrics emit no peer clauses.
        assert!(!Metrics::with_shards(2).summary().contains("peer["), "no peers expected");

        let text = m.render_prometheus();
        assert!(text.contains("peer_up{peer=\"1\"} 1"), "{text}");
        assert!(text.contains("peer_up{peer=\"0\"} 0"), "{text}");
        assert!(text.contains("peer_sent_total{peer=\"1\"} 12"), "{text}");
        assert!(text.contains("peer_send_errors_total{peer=\"2\"} 3"), "{text}");
        assert!(text.contains("peer_reconnects_total{peer=\"2\"} 2"), "{text}");
        assert!(text.contains("peer_frames_recv_total 40"), "{text}");
        assert!(!Metrics::new().render_prometheus().contains("peer_up"), "no peer families");
    }
}
