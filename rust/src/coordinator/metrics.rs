//! Lightweight serving metrics: counters and a log-scale latency
//! histogram, all lock-free on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log-scale latency buckets (1us .. ~1000s).
const NBUCKETS: usize = 64;

/// Serving metrics. All methods are thread-safe and wait-free.
#[derive(Debug)]
pub struct Metrics {
    /// Requests submitted.
    pub submitted: AtomicU64,
    /// Requests completed (replies delivered).
    pub completed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Sum of padded slots (for padding-overhead accounting).
    pub padded_slots: AtomicU64,
    /// Batches executed on the PJRT backend.
    pub pjrt_batches: AtomicU64,
    /// Batches executed on the native backend.
    pub native_batches: AtomicU64,
    hist: [AtomicU64; NBUCKETS],
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_slots: AtomicU64::new(0),
            pjrt_batches: AtomicU64::new(0),
            native_batches: AtomicU64::new(0),
            hist: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Metrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket(d: Duration) -> usize {
        let us = d.as_micros().max(1) as u64;
        (63 - us.leading_zeros() as usize).min(NBUCKETS - 1)
    }

    /// Record one request latency.
    pub fn record_latency(&self, d: Duration) {
        self.hist[Self::bucket(d)].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate latency quantile (upper bucket edge), in microseconds.
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.hist.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        u64::MAX
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} batches={} (pjrt={} native={}) padding={} p50<={}us p99<={}us",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.pjrt_batches.load(Ordering::Relaxed),
            self.native_batches.load(Ordering::Relaxed),
            self.padded_slots.load(Ordering::Relaxed),
            self.latency_quantile_us(0.5),
            self.latency_quantile_us(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bracket_recorded_latencies() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_latency(Duration::from_micros(100));
        }
        for _ in 0..5 {
            m.record_latency(Duration::from_millis(10));
        }
        let p50 = m.latency_quantile_us(0.5);
        let p99 = m.latency_quantile_us(0.99);
        assert!(p50 >= 100 && p50 < 1000, "p50 {p50}");
        assert!(p99 >= 8_000, "p99 {p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.99), 0);
    }
}
