//! Lightweight serving metrics: counters and a log-scale latency
//! histogram, all lock-free on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log-scale latency buckets (1us .. ~1000s).
const NBUCKETS: usize = 64;

/// Per-shard counters for sharded deployments (one entry per spatial
/// shard; see [`crate::shard`]). All wait-free atomics.
#[derive(Debug, Default)]
pub struct ShardMetrics {
    /// Owned observations absorbed by this shard's trainer.
    pub ingested: AtomicU64,
    /// Halo copies absorbed (points owned by a neighbor but within this
    /// shard's overlap coverage).
    pub halo_ingested: AtomicU64,
    /// Refresh + publish cycles completed by this shard.
    pub refreshes: AtomicU64,
    /// Cumulative refresh CG iterations (mean + probe solves) on this
    /// shard — the per-shard view of the preconditioner win (the
    /// global `last_refresh_*` gauges are unsharded-only; S workers
    /// racing one gauge would make its reading meaningless).
    pub refresh_cg_iters: AtomicU64,
    /// Wall-clock of this shard's most recent refresh, microseconds
    /// (single-writer: only the owning worker stores it) — the
    /// per-shard counterpart of the global `last_refresh_us` gauge, so
    /// the block-refresh speedup is observable in production on both
    /// server shapes.
    pub last_refresh_us: AtomicU64,
    /// Messages currently queued to this shard's worker (ingest
    /// back-pressure signal).
    pub queue_depth: AtomicU64,
    /// Prediction requests routed to this shard by the batcher.
    pub routed_predictions: AtomicU64,
}

/// Serving metrics. All methods are thread-safe and wait-free.
#[derive(Debug)]
pub struct Metrics {
    /// Requests submitted.
    pub submitted: AtomicU64,
    /// Requests completed (replies delivered).
    pub completed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Sum of padded slots (for padding-overhead accounting).
    pub padded_slots: AtomicU64,
    /// Batches executed on the PJRT backend.
    pub pjrt_batches: AtomicU64,
    /// Batches executed on the native backend.
    pub native_batches: AtomicU64,
    /// Streaming: observations absorbed by the ingest pipeline.
    pub ingested_points_total: AtomicU64,
    /// Streaming: per-point trainer-admission rejections (grid
    /// expansion cap; also non-finite values when the front-door batch
    /// check in `Server::ingest` is bypassed — that check errors whole
    /// batches before they reach the trainer, so those points are not
    /// counted here).
    pub ingest_rejected_total: AtomicU64,
    /// Streaming: ingest batches applied.
    pub ingest_batches: AtomicU64,
    /// Streaming: cache refreshes + model swaps completed.
    pub refresh_count: AtomicU64,
    /// Streaming: wall-clock of the most recent refresh, microseconds.
    pub last_refresh_us: AtomicU64,
    /// Streaming: CG iterations of the most recent refresh's mean
    /// solve (the preconditioner win is directly observable here).
    /// Unsharded servers only — sharded workers report per-shard
    /// cumulative counts in [`ShardMetrics::refresh_cg_iters`] instead
    /// of racing this gauge.
    pub last_refresh_mean_iters: AtomicU64,
    /// Streaming: total CG iterations across the most recent refresh's
    /// variance-probe solves (unsharded servers only, like
    /// [`Self::last_refresh_mean_iters`]).
    pub last_refresh_var_iters: AtomicU64,
    /// Streaming: cumulative refresh CG iterations (mean + probes)
    /// across all refreshes — the long-run iteration budget a
    /// preconditioner change moves.
    pub refresh_cg_iters_total: AtomicU64,
    /// Streaming: refreshes that requested a preconditioner but had to
    /// degrade to unpreconditioned CG (misconfigured refresh inputs).
    pub precond_fallbacks: AtomicU64,
    /// Streaming: thread count the in-tree pool had available during
    /// the most recent refresh (`1` = the batched FFT hot paths ran
    /// serially). Stored from `RefreshStats::threads` by the ingest
    /// loops; the live pool width is also exported as `pool_threads`.
    pub last_refresh_threads: AtomicU64,
    /// Streaming: hyperparameter re-optimizations completed.
    pub reopt_count: AtomicU64,
    /// Sharded serving: per-shard counters (empty on unsharded servers).
    pub shards: Vec<ShardMetrics>,
    hist: [AtomicU64; NBUCKETS],
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_slots: AtomicU64::new(0),
            pjrt_batches: AtomicU64::new(0),
            native_batches: AtomicU64::new(0),
            ingested_points_total: AtomicU64::new(0),
            ingest_rejected_total: AtomicU64::new(0),
            ingest_batches: AtomicU64::new(0),
            refresh_count: AtomicU64::new(0),
            last_refresh_us: AtomicU64::new(0),
            last_refresh_mean_iters: AtomicU64::new(0),
            last_refresh_var_iters: AtomicU64::new(0),
            refresh_cg_iters_total: AtomicU64::new(0),
            precond_fallbacks: AtomicU64::new(0),
            last_refresh_threads: AtomicU64::new(0),
            reopt_count: AtomicU64::new(0),
            shards: Vec::new(),
            hist: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Metrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fresh metrics with `n_shards` per-shard counter blocks.
    pub fn with_shards(n_shards: usize) -> Self {
        Metrics {
            shards: (0..n_shards).map(|_| ShardMetrics::default()).collect(),
            ..Default::default()
        }
    }

    fn bucket(d: Duration) -> usize {
        let us = d.as_micros().max(1) as u64;
        (63 - us.leading_zeros() as usize).min(NBUCKETS - 1)
    }

    /// Record one request latency.
    pub fn record_latency(&self, d: Duration) {
        self.hist[Self::bucket(d)].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate latency quantile (upper bucket edge), in microseconds.
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.hist.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        u64::MAX
    }

    /// Record a completed refresh (count + latency, one call so the two
    /// stay consistent).
    pub fn record_refresh(&self, d: Duration) {
        self.last_refresh_us.store(d.as_micros() as u64, Ordering::Relaxed);
        self.refresh_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one refresh's CG iteration counts (mean solve + total
    /// across the variance probes) — the signal that makes the
    /// preconditioner choice observable at `/metrics`. Called by the
    /// unsharded ingest loop only; shard workers update their
    /// [`ShardMetrics::refresh_cg_iters`] and the cumulative total
    /// directly, leaving the `last_*` gauges single-writer.
    pub fn record_refresh_cg(&self, mean_iters: u64, var_iters: u64) {
        self.last_refresh_mean_iters.store(mean_iters, Ordering::Relaxed);
        self.last_refresh_var_iters.store(var_iters, Ordering::Relaxed);
        self.refresh_cg_iters_total.fetch_add(mean_iters + var_iters, Ordering::Relaxed);
    }

    /// Record how many pool threads the most recent refresh had
    /// available (from `RefreshStats::threads`). Every shard worker
    /// reports the same process-wide value, so the sharded race on this
    /// gauge is benign.
    pub fn record_refresh_threads(&self, threads: u64) {
        self.last_refresh_threads.store(threads, Ordering::Relaxed);
    }

    /// One-line summary (the `/metrics` endpoint payload). Sharded
    /// servers append one `shard[i] ...` clause per shard.
    /// `pool_threads` and `fft_parallel_panels_total` are read live from
    /// the in-tree parallel layer ([`crate::parallel`] /
    /// [`crate::linalg::fft`]) so they stay accurate even for refreshes
    /// driven outside the coordinator.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "submitted={} completed={} batches={} (pjrt={} native={}) padding={} p50<={}us p99<={}us \
             ingested_points_total={} ingest_rejected_total={} ingest_batches={} refresh_count={} last_refresh_us={} \
             last_refresh_mean_iters={} last_refresh_var_iters={} refresh_cg_iters_total={} precond_fallbacks={} reopt_count={} \
             pool_threads={} fft_parallel_panels_total={} last_refresh_threads={}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.pjrt_batches.load(Ordering::Relaxed),
            self.native_batches.load(Ordering::Relaxed),
            self.padded_slots.load(Ordering::Relaxed),
            self.latency_quantile_us(0.5),
            self.latency_quantile_us(0.99),
            self.ingested_points_total.load(Ordering::Relaxed),
            self.ingest_rejected_total.load(Ordering::Relaxed),
            self.ingest_batches.load(Ordering::Relaxed),
            self.refresh_count.load(Ordering::Relaxed),
            self.last_refresh_us.load(Ordering::Relaxed),
            self.last_refresh_mean_iters.load(Ordering::Relaxed),
            self.last_refresh_var_iters.load(Ordering::Relaxed),
            self.refresh_cg_iters_total.load(Ordering::Relaxed),
            self.precond_fallbacks.load(Ordering::Relaxed),
            self.reopt_count.load(Ordering::Relaxed),
            crate::parallel::threads(),
            crate::linalg::fft::parallel_panels_total(),
            self.last_refresh_threads.load(Ordering::Relaxed),
        );
        for (i, sh) in self.shards.iter().enumerate() {
            s.push_str(&format!(
                " shard[{i}] ingested={} halo={} refreshes={} cg_iters={} last_refresh_us={} \
                 queue_depth={} routed={}",
                sh.ingested.load(Ordering::Relaxed),
                sh.halo_ingested.load(Ordering::Relaxed),
                sh.refreshes.load(Ordering::Relaxed),
                sh.refresh_cg_iters.load(Ordering::Relaxed),
                sh.last_refresh_us.load(Ordering::Relaxed),
                sh.queue_depth.load(Ordering::Relaxed),
                sh.routed_predictions.load(Ordering::Relaxed),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bracket_recorded_latencies() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_latency(Duration::from_micros(100));
        }
        for _ in 0..5 {
            m.record_latency(Duration::from_millis(10));
        }
        let p50 = m.latency_quantile_us(0.5);
        let p99 = m.latency_quantile_us(0.99);
        assert!(p50 >= 100 && p50 < 1000, "p50 {p50}");
        assert!(p99 >= 8_000, "p99 {p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.99), 0);
    }

    #[test]
    fn per_shard_counters_appear_in_summary() {
        let m = Metrics::with_shards(2);
        m.shards[0].ingested.fetch_add(10, Ordering::Relaxed);
        m.shards[1].halo_ingested.fetch_add(3, Ordering::Relaxed);
        m.shards[1].queue_depth.fetch_add(5, Ordering::Relaxed);
        m.shards[0].refresh_cg_iters.fetch_add(42, Ordering::Relaxed);
        m.shards[0].last_refresh_us.store(777, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("shard[0] ingested=10"), "{s}");
        assert!(s.contains("halo=3"), "{s}");
        assert!(s.contains("queue_depth=5"), "{s}");
        assert!(s.contains("cg_iters=42"), "{s}");
        assert!(s.contains("last_refresh_us=777"), "{s}");
        // Unsharded metrics emit no shard clauses.
        assert!(!Metrics::new().summary().contains("shard[0]"));
    }

    #[test]
    fn streaming_counters_appear_in_summary() {
        let m = Metrics::new();
        m.ingested_points_total.fetch_add(123, Ordering::Relaxed);
        m.record_refresh(Duration::from_micros(456));
        let s = m.summary();
        assert!(s.contains("ingested_points_total=123"), "{s}");
        assert!(s.contains("refresh_count=1"), "{s}");
        assert!(s.contains("last_refresh_us=456"), "{s}");
    }

    #[test]
    fn refresh_cg_counters_accumulate_and_appear_in_summary() {
        let m = Metrics::new();
        m.record_refresh_cg(12, 80);
        m.record_refresh_cg(7, 40);
        assert_eq!(m.last_refresh_mean_iters.load(Ordering::Relaxed), 7);
        assert_eq!(m.last_refresh_var_iters.load(Ordering::Relaxed), 40);
        assert_eq!(m.refresh_cg_iters_total.load(Ordering::Relaxed), 139);
        m.precond_fallbacks.fetch_add(2, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("last_refresh_mean_iters=7"), "{s}");
        assert!(s.contains("last_refresh_var_iters=40"), "{s}");
        assert!(s.contains("refresh_cg_iters_total=139"), "{s}");
        assert!(s.contains("precond_fallbacks=2"), "{s}");
    }

    #[test]
    fn parallel_gauges_appear_in_summary() {
        let m = Metrics::new();
        m.record_refresh_threads(3);
        let s = m.summary();
        assert!(s.contains("last_refresh_threads=3"), "{s}");
        assert!(s.contains("fft_parallel_panels_total="), "{s}");
        // pool_threads reads the live pool width; concurrent tests may
        // reconfigure it between reads, so only pin its presence.
        assert!(s.contains("pool_threads="), "{s}");
    }
}
