//! Rectilinear inducing-point grids `U = U_1 x ... x U_D`.
//!
//! MSGP places the inducing points on a regularly spaced Cartesian product
//! grid so that `K_{U,U}` inherits Kronecker-of-Toeplitz (or BTTB)
//! structure, while the *data* inputs remain arbitrary (section 5.2).

/// One regularly spaced axis of a product grid.
#[derive(Clone, Debug, PartialEq)]
pub struct GridAxis {
    /// Left edge (coordinate of the first grid point).
    pub lo: f64,
    /// Spacing between consecutive points.
    pub step: f64,
    /// Number of points.
    pub n: usize,
}

impl GridAxis {
    /// Build an axis spanning `[lo, hi]` with `n` points.
    pub fn span(lo: f64, hi: f64, n: usize) -> Self {
        assert!(n >= 2, "grid axis needs at least 2 points");
        assert!(hi > lo);
        GridAxis { lo, step: (hi - lo) / (n - 1) as f64, n }
    }

    /// Coordinate of grid point `i`.
    #[inline]
    pub fn coord(&self, i: usize) -> f64 {
        self.lo + self.step * i as f64
    }

    /// Map a coordinate to continuous grid units (`0 .. n-1`).
    #[inline]
    pub fn to_units(&self, x: f64) -> f64 {
        (x - self.lo) / self.step
    }
}

/// A D-dimensional rectilinear grid.
#[derive(Clone, Debug, PartialEq)]
pub struct Grid {
    /// Per-dimension axes.
    pub axes: Vec<GridAxis>,
}

impl Grid {
    /// Build from axes.
    pub fn new(axes: Vec<GridAxis>) -> Self {
        assert!(!axes.is_empty());
        Grid { axes }
    }

    /// Build a grid covering the bounding box of `points` (rows of `dim`
    /// coordinates), expanded by `margin_cells` grid cells on each side so
    /// that the cubic interpolation stencil never leaves the grid.
    pub fn covering(points: &[f64], dim: usize, n_per_dim: &[usize], margin_cells: usize) -> Self {
        assert_eq!(n_per_dim.len(), dim);
        assert!(points.len() % dim == 0);
        let npts = points.len() / dim;
        assert!(npts > 0);
        let mut axes = Vec::with_capacity(dim);
        for d in 0..dim {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for p in 0..npts {
                let v = points[p * dim + d];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if hi - lo < 1e-12 {
                hi = lo + 1.0;
            }
            let n = n_per_dim[d];
            assert!(n > 2 * margin_cells + 1, "grid too small for margin");
            let inner = (n - 1 - 2 * margin_cells) as f64;
            let step = (hi - lo) / inner;
            axes.push(GridAxis { lo: lo - margin_cells as f64 * step, step, n });
        }
        Grid { axes }
    }

    /// Dimensionality.
    pub fn dim(&self) -> usize {
        self.axes.len()
    }

    /// Per-dimension sizes.
    pub fn shape(&self) -> Vec<usize> {
        self.axes.iter().map(|a| a.n).collect()
    }

    /// Total number of grid points `m`.
    pub fn m(&self) -> usize {
        self.axes.iter().map(|a| a.n).product()
    }

    /// Flatten a multi-index (row-major: last axis fastest).
    pub fn flat(&self, idx: &[usize]) -> usize {
        let mut f = 0usize;
        for (a, &i) in self.axes.iter().zip(idx) {
            debug_assert!(i < a.n);
            f = f * a.n + i;
        }
        f
    }

    /// Coordinates of the flat grid point `f` (row-major).
    pub fn point(&self, mut f: usize) -> Vec<f64> {
        let d = self.dim();
        let mut out = vec![0.0; d];
        for a in (0..d).rev() {
            let n = self.axes[a].n;
            out[a] = self.axes[a].coord(f % n);
            f /= n;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axis_units_roundtrip() {
        let a = GridAxis::span(-2.0, 3.0, 11);
        assert!((a.step - 0.5).abs() < 1e-12);
        assert!((a.to_units(a.coord(7)) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn covering_has_margin() {
        let pts = vec![0.0, 0.0, 1.0, 2.0, -1.0, 4.0]; // 3 points in 2-D
        let g = Grid::covering(&pts, 2, &[10, 12], 2);
        assert_eq!(g.shape(), vec![10, 12]);
        // Every data coordinate must be at least margin cells inside.
        for p in 0..3 {
            for d in 0..2 {
                let u = g.axes[d].to_units(pts[p * 2 + d]);
                assert!(u >= 2.0 - 1e-9 && u <= (g.axes[d].n - 3) as f64 + 1e-9, "u={u}");
            }
        }
    }

    #[test]
    fn flat_and_point_roundtrip() {
        let g = Grid::new(vec![GridAxis::span(0.0, 1.0, 3), GridAxis::span(0.0, 1.0, 4)]);
        assert_eq!(g.m(), 12);
        for f in 0..12 {
            let p = g.point(f);
            let i0 = (0..3).min_by_key(|&i| ((g.axes[0].coord(i) - p[0]).abs() * 1e6) as i64).unwrap();
            let i1 = (0..4).min_by_key(|&i| ((g.axes[1].coord(i) - p[1]).abs() * 1e6) as i64).unwrap();
            assert_eq!(g.flat(&[i0, i1]), f);
        }
    }
}
