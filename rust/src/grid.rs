//! Rectilinear inducing-point grids `U = U_1 x ... x U_D`.
//!
//! MSGP places the inducing points on a regularly spaced Cartesian product
//! grid so that `K_{U,U}` inherits Kronecker-of-Toeplitz (or BTTB)
//! structure, while the *data* inputs remain arbitrary (section 5.2).

/// One regularly spaced axis of a product grid.
#[derive(Clone, Debug, PartialEq)]
pub struct GridAxis {
    /// Left edge (coordinate of the first grid point).
    pub lo: f64,
    /// Spacing between consecutive points.
    pub step: f64,
    /// Number of points.
    pub n: usize,
}

impl GridAxis {
    /// Build an axis spanning `[lo, hi]` with `n` points.
    pub fn span(lo: f64, hi: f64, n: usize) -> Self {
        assert!(n >= 2, "grid axis needs at least 2 points");
        assert!(hi > lo);
        GridAxis { lo, step: (hi - lo) / (n - 1) as f64, n }
    }

    /// Coordinate of grid point `i`.
    #[inline]
    pub fn coord(&self, i: usize) -> f64 {
        self.lo + self.step * i as f64
    }

    /// Map a coordinate to continuous grid units (`0 .. n-1`).
    #[inline]
    pub fn to_units(&self, x: f64) -> f64 {
        (x - self.lo) / self.step
    }

    /// The same axis grown by whole cells on each side: `step` is
    /// preserved, so every existing grid point keeps its coordinate
    /// (its index shifts by `left`).
    pub fn extended(&self, left: usize, right: usize) -> GridAxis {
        GridAxis {
            lo: self.lo - left as f64 * self.step,
            step: self.step,
            n: self.n + left + right,
        }
    }
}

/// Whole-cell growth of a [`Grid`], per dimension. Because the step is
/// preserved, sufficient statistics indexed by grid cell stay valid
/// under the index shift `i -> i + added_lo[d]` — the contract the
/// streaming subsystem's remapping relies on.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GridExpansion {
    /// Cells added below the old origin, per dimension.
    pub added_lo: Vec<usize>,
    /// Cells added above the old top, per dimension.
    pub added_hi: Vec<usize>,
}

impl GridExpansion {
    /// True when no dimension grew.
    pub fn is_empty(&self) -> bool {
        self.added_lo.iter().all(|&a| a == 0) && self.added_hi.iter().all(|&a| a == 0)
    }
}

/// A D-dimensional rectilinear grid.
#[derive(Clone, Debug, PartialEq)]
pub struct Grid {
    /// Per-dimension axes.
    pub axes: Vec<GridAxis>,
}

impl Grid {
    /// Build from axes.
    pub fn new(axes: Vec<GridAxis>) -> Self {
        assert!(!axes.is_empty());
        Grid { axes }
    }

    /// Build a grid covering the bounding box of `points` (rows of `dim`
    /// coordinates), expanded by `margin_cells` grid cells on each side so
    /// that the cubic interpolation stencil never leaves the grid.
    pub fn covering(points: &[f64], dim: usize, n_per_dim: &[usize], margin_cells: usize) -> Self {
        assert_eq!(n_per_dim.len(), dim);
        assert!(points.len() % dim == 0);
        let npts = points.len() / dim;
        assert!(npts > 0);
        let mut axes = Vec::with_capacity(dim);
        for d in 0..dim {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for p in 0..npts {
                let v = points[p * dim + d];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if hi - lo < 1e-12 {
                hi = lo + 1.0;
            }
            let n = n_per_dim[d];
            assert!(n > 2 * margin_cells + 1, "grid too small for margin");
            let inner = (n - 1 - 2 * margin_cells) as f64;
            let step = (hi - lo) / inner;
            axes.push(GridAxis { lo: lo - margin_cells as f64 * step, step, n });
        }
        Grid { axes }
    }

    /// Dimensionality.
    pub fn dim(&self) -> usize {
        self.axes.len()
    }

    /// Per-dimension sizes.
    pub fn shape(&self) -> Vec<usize> {
        self.axes.iter().map(|a| a.n).collect()
    }

    /// Total number of grid points `m`.
    pub fn m(&self) -> usize {
        self.axes.iter().map(|a| a.n).product()
    }

    /// Flatten a multi-index (row-major: last axis fastest).
    pub fn flat(&self, idx: &[usize]) -> usize {
        let mut f = 0usize;
        for (a, &i) in self.axes.iter().zip(idx) {
            debug_assert!(i < a.n);
            f = f * a.n + i;
        }
        f
    }

    /// Coordinates of the flat grid point `f` (row-major).
    pub fn point(&self, mut f: usize) -> Vec<f64> {
        let d = self.dim();
        let mut out = vec![0.0; d];
        for a in (0..d).rev() {
            let n = self.axes[a].n;
            out[a] = self.axes[a].coord(f % n);
            f /= n;
        }
        out
    }

    /// True when `x` sits at least `margin` cells inside every axis — the
    /// region where the cubic stencil needs no inward shifting. A small
    /// unit tolerance absorbs `to_units` rounding so points placed
    /// exactly on the margin count as covered.
    pub fn covers(&self, x: &[f64], margin: f64) -> bool {
        debug_assert_eq!(x.len(), self.dim());
        const EPS: f64 = 1e-9;
        self.axes.iter().zip(x).all(|(ax, &v)| {
            let u = ax.to_units(v);
            u >= margin - EPS && u <= (ax.n - 1) as f64 - margin + EPS
        })
    }

    /// Whole-cell expansion needed so that `x` lies at least
    /// `margin_cells` cells inside every axis; `None` when the grid
    /// already covers it (up to the same unit tolerance as
    /// [`Self::covers`], so margin-exact points never trigger a spurious
    /// one-cell expansion). The step never changes, so the expansion is
    /// purely additive (see [`GridExpansion`]).
    pub fn expansion_to_cover(&self, x: &[f64], margin_cells: usize) -> Option<GridExpansion> {
        debug_assert_eq!(x.len(), self.dim());
        const EPS: f64 = 1e-9;
        let m = margin_cells as f64;
        let mut added_lo = vec![0usize; self.dim()];
        let mut added_hi = vec![0usize; self.dim()];
        let mut any = false;
        for (d, (ax, &v)) in self.axes.iter().zip(x).enumerate() {
            let u = ax.to_units(v);
            if u < m - EPS {
                added_lo[d] = (m - u).ceil() as usize;
                any = true;
            }
            let top = (ax.n - 1) as f64 - m;
            if u > top + EPS {
                added_hi[d] = (u - top).ceil() as usize;
                any = true;
            }
        }
        any.then_some(GridExpansion { added_lo, added_hi })
    }

    /// Apply an expansion, producing the grown grid.
    pub fn expanded(&self, exp: &GridExpansion) -> Grid {
        assert_eq!(exp.added_lo.len(), self.dim());
        assert_eq!(exp.added_hi.len(), self.dim());
        Grid {
            axes: self
                .axes
                .iter()
                .enumerate()
                .map(|(d, ax)| ax.extended(exp.added_lo[d], exp.added_hi[d]))
                .collect(),
        }
    }

    /// Per-dimension index shift of this grid's cells inside `new` (which
    /// must be an expansion of this grid with the same steps). Used to
    /// remap flat-indexed grid vectors after auto-expansion.
    pub fn shift_within(&self, new: &Grid) -> Vec<usize> {
        assert_eq!(self.dim(), new.dim());
        self.axes
            .iter()
            .zip(&new.axes)
            .map(|(old, nw)| {
                let s = (old.lo - nw.lo) / nw.step;
                let r = s.round();
                assert!(
                    (s - r).abs() < 1e-6 && r >= 0.0,
                    "grid is not a whole-cell expansion (shift {s})"
                );
                r as usize
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axis_units_roundtrip() {
        let a = GridAxis::span(-2.0, 3.0, 11);
        assert!((a.step - 0.5).abs() < 1e-12);
        assert!((a.to_units(a.coord(7)) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn covering_has_margin() {
        let pts = vec![0.0, 0.0, 1.0, 2.0, -1.0, 4.0]; // 3 points in 2-D
        let g = Grid::covering(&pts, 2, &[10, 12], 2);
        assert_eq!(g.shape(), vec![10, 12]);
        // Every data coordinate must be at least margin cells inside.
        for p in 0..3 {
            for d in 0..2 {
                let u = g.axes[d].to_units(pts[p * 2 + d]);
                assert!(u >= 2.0 - 1e-9 && u <= (g.axes[d].n - 3) as f64 + 1e-9, "u={u}");
            }
        }
    }

    #[test]
    fn expansion_preserves_existing_points() {
        let g = Grid::new(vec![GridAxis::span(0.0, 4.0, 9), GridAxis::span(-1.0, 1.0, 5)]);
        // A point far left in dim 0 and far right in dim 1.
        let x = [-1.3, 1.9];
        assert!(!g.covers(&x, 2.0));
        let exp = g.expansion_to_cover(&x, 2).unwrap();
        let g2 = g.expanded(&exp);
        assert!(g2.covers(&x, 2.0), "expanded grid must cover the point");
        // Steps unchanged; old grid points keep their coordinates.
        for d in 0..2 {
            assert!((g2.axes[d].step - g.axes[d].step).abs() < 1e-12);
        }
        let shift = g.shift_within(&g2);
        assert_eq!(shift, exp.added_lo);
        for d in 0..2 {
            for i in 0..g.axes[d].n {
                let old = g.axes[d].coord(i);
                let new = g2.axes[d].coord(i + shift[d]);
                assert!((old - new).abs() < 1e-12);
            }
        }
        // Covered point expands to nothing.
        assert!(g2.expansion_to_cover(&x, 2).is_none());
    }

    #[test]
    fn flat_and_point_roundtrip() {
        let g = Grid::new(vec![GridAxis::span(0.0, 1.0, 3), GridAxis::span(0.0, 1.0, 4)]);
        assert_eq!(g.m(), 12);
        for f in 0..12 {
            let p = g.point(f);
            let i0 = (0..3).min_by_key(|&i| ((g.axes[0].coord(i) - p[0]).abs() * 1e6) as i64).unwrap();
            let i1 = (0..4).min_by_key(|&i| ((g.axes[1].coord(i) - p[1]).abs() * 1e6) as i64).unwrap();
            assert_eq!(g.flat(&[i0, i1]), f);
        }
    }
}
