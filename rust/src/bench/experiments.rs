//! The per-figure experiment drivers. All output is plain-text tables
//! (one row per plotted point) so the results can be diffed against
//! EXPERIMENTS.md.

use std::time::{Duration, Instant};

use crate::data::{gen_projection_data, gen_stress_1d, smae};
use crate::gp::exact::ExactGp;
use crate::gp::fitc::Fitc;
use crate::gp::msgp::{subspace_dist, KernelSpec, LogdetMethod, MsgpConfig, MsgpModel, ProjMsgp};
use crate::gp::ssgp::Ssgp;
use crate::gp::svigp::{Svigp, SvigpConfig};
use crate::grid::{Grid, GridAxis};
use crate::kernels::{KernelType, ProductKernel};
use crate::structure::circulant::{circulant_approx, CirculantKind};
use crate::structure::toeplitz::SymToeplitz;
use crate::util::Rng;

fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Figure 1 (+ appendix figs 6-9): relative log-det error of the five
/// circulant approximations vs grid size, across kernels, lengthscales
/// and noise levels. Exact reference: Levinson O(m^2) Toeplitz log-det.
pub fn fig1_circulant(full: bool) {
    let kernels: Vec<(KernelType, &str)> = vec![
        (KernelType::SE, "covSE"),
        (KernelType::Matern32, "covMatern32"),
        (KernelType::rq(2.0), "covRQ(2)"),
    ];
    let ells = if full { vec![2.0, 8.0, 32.0] } else { vec![4.0, 16.0] };
    let sigmas = if full { vec![1e-4, 1e-2, 1.0] } else { vec![1e-2, 1.0] };
    let ms: Vec<usize> = if full {
        vec![64, 128, 256, 512, 1024, 2048, 4096]
    } else {
        vec![64, 256, 1024]
    };
    println!("# Figure 1: circulant log-det approximations (relative error vs exact)");
    println!(
        "{:<14} {:>6} {:>8} {:>7}  {:>10} {:>10} {:>10} {:>10} {:>10}",
        "kernel", "ell", "sigma2", "m", "strang", "tchan", "tyrt", "helgason", "whittle"
    );
    for (kt, name) in &kernels {
        for &ell in &ells {
            for &s2 in &sigmas {
                for &m in &ms {
                    // Lengthscale in grid units (step = 1).
                    let col: Vec<f64> = (0..m).map(|i| kt.corr(i as f64, ell)).collect();
                    let t = SymToeplitz::new(col.clone());
                    let Some(exact) = t.logdet_levinson(s2) else {
                        continue;
                    };
                    let tail = |lag: usize| kt.corr(lag as f64, ell);
                    let mut errs = Vec::new();
                    for kind in CirculantKind::ALL {
                        let c = if kind == CirculantKind::Whittle {
                            circulant_approx(kind, &col, 3, Some(&tail))
                        } else if kind == CirculantKind::Tyrtyshnikov && m > 2048 {
                            // O(m^2)/O(m^3) construction; cap like the paper's
                            // benchmarks do.
                            errs.push(f64::NAN);
                            continue;
                        } else {
                            circulant_approx(kind, &col, 0, None)
                        };
                        let approx = c.logdet(s2);
                        errs.push((approx - exact).abs() / exact.abs());
                    }
                    print!("{:<14} {:>6.1} {:>8.0e} {:>7}", name, ell, s2, m);
                    for e in errs {
                        if e.is_nan() {
                            print!(" {:>10}", "-");
                        } else {
                            print!(" {:>10.2e}", e);
                        }
                    }
                    println!();
                }
            }
        }
    }
}

/// One training-cost evaluation (NLML + all derivatives) per method, as
/// timed in Figure 2. Returns seconds.
pub fn time_training_eval(method: &str, n: usize, m: usize, seed: u64) -> Option<f64> {
    let data = gen_stress_1d(n, 0.05, seed);
    let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
    match method {
        "exact" => {
            let (gp, t_fit) = time_it(|| ExactGp::fit(kernel, 0.01, data).unwrap());
            let (_, t_grad) = time_it(|| gp.lml_grad());
            Some(t_fit + t_grad)
        }
        "fitc" => {
            let (f, t_fit) =
                time_it(|| Fitc::fit_grid_1d(kernel, 0.01, data, m, -12.0, 13.0).unwrap());
            let (_, t_grad) = time_it(|| f.lml_fd_grad());
            Some(t_fit + t_grad)
        }
        "ssgp" => {
            let (s, t_fit) = time_it(|| Ssgp::fit(kernel, 0.01, data, m, seed).unwrap());
            let (_, t_grad) = time_it(|| s.lml_fd_grad());
            Some(t_fit + t_grad)
        }
        "bdgp" => {
            // One SVI step on a 300-point minibatch (per-step cost is what
            // scales; convergence is a separate axis the paper discusses).
            let cfg = SvigpConfig { batch: 300, max_steps: 1, learn_hypers: true, ..Default::default() };
            let (_, t) =
                time_it(|| Svigp::train_grid_1d(kernel, 0.01, &data, m, -12.0, 13.0, cfg).unwrap());
            Some(t)
        }
        "msgp" | "msgp-toeplitz" => {
            let logdet = if method == "msgp" {
                LogdetMethod::Circulant(CirculantKind::Whittle)
            } else {
                LogdetMethod::ToeplitzExact
            };
            let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
            let cfg = MsgpConfig { n_per_dim: vec![m], logdet, ..Default::default() };
            let (model, t_fit) = time_it(|| {
                MsgpModel::fit_with_grid(
                    KernelSpec::Product(kernel),
                    0.01,
                    data,
                    grid,
                    cfg,
                )
                .unwrap()
            });
            let (_, t_grad) = time_it(|| model.lml_grad());
            Some(t_fit + t_grad)
        }
        _ => None,
    }
}

/// Figure 2: training runtime (marginal likelihood + derivatives) vs n
/// for each method, and vs m for MSGP.
pub fn fig2_training(full: bool) {
    println!("# Figure 2: training runtime (one NLML + derivatives evaluation), seconds");
    println!("{:<16} {:>9} {:>9} {:>12}", "method", "n", "m", "seconds");
    let ns_small: Vec<usize> = if full {
        vec![250, 500, 1000, 2000]
    } else {
        vec![250, 500, 1000]
    };
    let ns_mid: Vec<usize> =
        if full { vec![1000, 4000, 16000] } else { vec![1000, 4000] };
    let ns_big: Vec<usize> = if full {
        vec![1000, 10_000, 100_000, 1_000_000]
    } else {
        vec![1000, 10_000, 100_000]
    };
    for &n in &ns_small {
        if let Some(t) = time_training_eval("exact", n, 0, 1) {
            println!("{:<16} {:>9} {:>9} {:>12.4}", "exact", n, "-", t);
        }
    }
    for method in ["fitc", "ssgp", "bdgp"] {
        let m = 256;
        for &n in &ns_mid {
            if let Some(t) = time_training_eval(method, n, m, 1) {
                println!("{:<16} {:>9} {:>9} {:>12.4}", method, n, m, t);
            }
        }
    }
    // MSGP-Toeplitz ablation: the O(m^2)-logdet pathway limits m.
    for &n in &ns_mid {
        if let Some(t) = time_training_eval("msgp-toeplitz", n, 1000, 1) {
            println!("{:<16} {:>9} {:>9} {:>12.4}", "msgp-toeplitz", n, 1000, t);
        }
    }
    // MSGP: sweep n and m — the paper's headline (runtime flat in m).
    let msgp_ms: Vec<usize> = if full {
        vec![1_000, 10_000, 100_000, 1_000_000]
    } else {
        vec![1_000, 10_000, 100_000]
    };
    for &m in &msgp_ms {
        for &n in &ns_big {
            if let Some(t) = time_training_eval("msgp", n, m, 1) {
                println!("{:<16} {:>9} {:>9} {:>12.4}", "msgp", n, m, t);
            }
        }
    }
}

/// Figure 3: prediction runtime per test point (mean + variance), after
/// training-time precomputation.
pub fn fig3_prediction(full: bool) {
    println!("# Figure 3: prediction runtime for n* = 1000 test points, seconds");
    println!("{:<18} {:>9} {:>9} {:>14} {:>14}", "method", "n", "m", "mean_s", "var_s");
    let n_star = 1000usize;
    let test = gen_stress_1d(n_star, 0.0, 999);
    let ns: Vec<usize> = if full { vec![1000, 4000, 16000] } else { vec![1000, 4000] };
    let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
    for &n in &ns {
        let data = gen_stress_1d(n, 0.05, 2);
        // Exact GP (variance timed on a 100-point subsample and scaled:
        // O(n^2) per point makes the full 1000 prohibitive at n = 4000).
        if n <= 4000 {
            let gp = ExactGp::fit(kernel.clone(), 0.01, data.clone()).unwrap();
            let (_, tm) = time_it(|| gp.predict_mean(&test.x));
            let sub: Vec<f64> = test.x[..100].to_vec();
            let (_, tv) = time_it(|| gp.predict_var(&sub));
            println!(
                "{:<18} {:>9} {:>9} {:>14.5} {:>14.5}",
                "exact",
                n,
                "-",
                tm,
                tv * (n_star as f64 / 100.0)
            );
        }
        // FITC / SSGP with m = 256.
        let m = 256;
        let fitc = Fitc::fit_grid_1d(kernel.clone(), 0.01, data.clone(), m, -12.0, 13.0).unwrap();
        let (_, tm) = time_it(|| fitc.predict_mean(&test.x));
        let (_, tv) = time_it(|| fitc.predict_var(&test.x));
        println!("{:<18} {:>9} {:>9} {:>14.5} {:>14.5}", "fitc", n, m, tm, tv);
        let ssgp = Ssgp::fit(kernel.clone(), 0.01, data.clone(), m, 3).unwrap();
        let (_, tm) = time_it(|| ssgp.predict_mean(&test.x));
        let (_, tv) = time_it(|| ssgp.predict_var(&test.x));
        println!("{:<18} {:>9} {:>9} {:>14.5} {:>14.5}", "ssgp", n, m, tm, tv);
        // MSGP fast vs slow, m sweep.
        let msgp_ms: Vec<usize> = if full { vec![1000, 10000, 100000] } else { vec![1000, 10000] };
        for &mm in &msgp_ms {
            let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, mm)]);
            let cfg = MsgpConfig { n_per_dim: vec![mm], ..Default::default() };
            let mut model = MsgpModel::fit_with_grid(
                KernelSpec::Product(kernel.clone()),
                0.01,
                data.clone(),
                grid,
                cfg,
            )
            .unwrap();
            model.precompute_variance();
            let (_, tm) = time_it(|| model.predict_mean(&test.x));
            let (_, tv) = time_it(|| model.predict_var(&test.x));
            println!("{:<18} {:>9} {:>9} {:>14.5} {:>14.5}", "msgp-fast", n, mm, tm, tv);
            if mm <= 1000 && n <= 4000 {
                let (_, tms) = time_it(|| model.predict_mean_slow(&test.x));
                let few: Vec<f64> = test.x[..50].to_vec();
                let (_, tvs) = time_it(|| model.predict_var_slow(&few));
                println!(
                    "{:<18} {:>9} {:>9} {:>14.5} {:>14.5}",
                    "msgp-slow",
                    n,
                    mm,
                    tms,
                    tvs * (n_star as f64 / 50.0)
                );
            }
        }
    }
}

/// Figure 4: accuracy of the fast predictions vs the slow SKI predictions
/// vs exact inference, as a function of m and n_s.
pub fn fig4_accuracy(full: bool) {
    println!("# Figure 4: SMAE of predictive mean / mean-abs-rel-err of variance vs exact GP");
    println!(
        "{:<8} {:>6} {:>6}  {:>12} {:>12} {:>12} {:>12}",
        "n", "m", "n_s", "mean_fast", "mean_slow", "varF/sf2", "varS/sf2"
    );
    let n = if full { 4000 } else { 1500 };
    let data = gen_stress_1d(n, 0.05, 4);
    let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
    let gp = ExactGp::fit(kernel.clone(), 0.01, data.clone()).unwrap();
    let test = gen_stress_1d(500, 0.0, 1234);
    let gold_mean = gp.predict_mean(&test.x);
    // Compare observation-space variances (latent + sigma2): the latent
    // variance is ~0 near dense data, which makes pointwise relative
    // errors meaningless; aggregate normalization keeps the metric stable.
    let gold_var: Vec<f64> = gp.predict_var(&test.x).iter().map(|v| v + gp.sigma2).collect();
    let ms: Vec<usize> = if full { vec![64, 128, 256, 512, 1024] } else { vec![64, 256, 512] };
    let nss: Vec<usize> = if full { vec![5, 20, 80] } else { vec![5, 20] };
    for &m in &ms {
        for &ns in &nss {
            let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
            let cfg = MsgpConfig { n_per_dim: vec![m], n_var_samples: ns, ..Default::default() };
            let mut model = MsgpModel::fit_with_grid(
                KernelSpec::Product(kernel.clone()),
                0.01,
                data.clone(),
                grid,
                cfg,
            )
            .unwrap();
            let fast_mean = model.predict_mean(&test.x);
            let slow_mean = model.predict_mean_slow(&test.x);
            let sigma2 = model.sigma2;
            let fast_var: Vec<f64> =
                model.predict_var(&test.x).iter().map(|v| v + sigma2).collect();
            // Mean absolute variance error on the signal-variance scale
            // (the gold latent variance is ~0 near dense data, so dividing
            // by it is uninformative; sf2 is the natural scale of Eq. 10's
            // subtraction and of the estimator's noise).
            let sf2 = model.kernel.sf2();
            let var_err = move |pred: &[f64], gold: &[f64]| -> f64 {
                let num: f64 = pred.iter().zip(gold).map(|(p, g)| (p - g).abs()).sum();
                num / (sf2 * pred.len() as f64)
            };
            // Slow variance on a subsample (O(n) CG solve per point).
            let sub: Vec<f64> = test.x.iter().step_by(10).copied().collect();
            let slow_var: Vec<f64> =
                model.predict_var_slow(&sub).iter().map(|v| v + sigma2).collect();
            let gold_var_sub: Vec<f64> = gold_var.iter().step_by(10).copied().collect();
            let slow_var_err = var_err(&slow_var, &gold_var_sub);
            println!(
                "{:<8} {:>6} {:>6}  {:>12.4} {:>12.4} {:>12.4} {:>12.4}",
                n,
                m,
                ns,
                smae(&fast_mean, &gold_mean),
                smae(&slow_mean, &gold_mean),
                var_err(&fast_var, &gold_var),
                slow_var_err
            );
        }
    }
}

/// Figure 5: supervised projection consistency — subspace recovery error
/// and SMAE vs input dimension D.
pub fn fig5_projections(full: bool) {
    println!("# Figure 5: projections — subspace error (a) and SMAE (b) vs D");
    println!(
        "{:<6} {:>6}  {:>12} {:>12} {:>12} {:>12}",
        "D", "rep", "subspace", "smae_proj", "smae_full", "smae_true"
    );
    let n = if full { 3000 } else { 2500 };
    let n_test = if full { 1000 } else { 200 };
    let reps = if full { 5 } else { 2 };
    let dims: Vec<usize> = if full {
        vec![3, 5, 10, 20, 40, 70, 100]
    } else {
        vec![3, 5, 10, 20]
    };
    let d = 2usize;
    for &bigd in &dims {
        for rep in 0..reps {
            let seed = 1000 + rep as u64 * 17 + bigd as u64;
            let kern = ProductKernel::iso(KernelType::SE, d, 1.5, 1.0);
            let pd = gen_projection_data(n + n_test, bigd, d, &kern, 0.05, seed);
            // Split train/test.
            let train = crate::data::Dataset {
                x: pd.data.x[..n * bigd].to_vec(),
                d: bigd,
                y: pd.data.y[..n].to_vec(),
            };
            let test_x = &pd.data.x[n * bigd..];
            let test_y = &pd.data.y[n..];
            let test_low = &pd.x_low[n * d..];
            // MSGP with learned projection (ridge-informed first row).
            // The marginal likelihood has an explain-as-noise local
            // optimum; detect the collapse (sigma2 near var(y)) and retry
            // once from a different start, keeping the better LML — the
            // paper's 30-replication averages play the same role.
            let cfg = MsgpConfig {
                n_per_dim: vec![50, 50],
                n_var_samples: 5,
                ..Default::default()
            };
            let var_y = {
                let my = train.y.iter().sum::<f64>() / train.y.len() as f64;
                train.y.iter().map(|v| (v - my) * (v - my)).sum::<f64>() / train.y.len() as f64
            };
            let iters = 150;
            let run_once = |s: u64| -> ProjMsgp {
                let p0 = ProjMsgp::informed_init(d, &train, s);
                let mut proj =
                    ProjMsgp::fit(p0, kern.clone(), 0.05, train.clone(), cfg.clone()).unwrap();
                proj.train_with(iters, 0.05, true).unwrap();
                proj.train_with(iters, 0.05, false).unwrap();
                proj
            };
            let mut proj = run_once(seed ^ 0xabc);
            if proj.model.sigma2 > 0.3 * var_y {
                let retry = run_once(seed ^ 0xdef0);
                if retry.model.lml() > proj.model.lml() {
                    proj = retry;
                }
            }
            let sub_err = proj.subspace_error(&pd.p_true);
            let pred = proj.predict_mean(test_x);
            let smae_proj = smae(&pred, test_y);
            // Exact GP on the raw high-dimensional inputs (GP Full).
            let full_kern = ProductKernel::iso(KernelType::SE, bigd, 2.0, 1.0);
            let gp_full = ExactGp::fit(full_kern, 0.05, train.clone()).unwrap();
            let smae_full = smae(&gp_full.predict_mean(test_x), test_y);
            // Exact GP on the true low-dimensional inputs (GP True).
            let train_low = crate::data::Dataset {
                x: pd.x_low[..n * d].to_vec(),
                d,
                y: train.y.clone(),
            };
            let gp_true = ExactGp::fit(kern.clone(), 0.05, train_low).unwrap();
            let smae_true = smae(&gp_true.predict_mean(test_low), test_y);
            println!(
                "{:<6} {:>6}  {:>12.4} {:>12.4} {:>12.4} {:>12.4}",
                bigd, rep, sub_err, smae_proj, smae_full, smae_true
            );
        }
    }
    let _ = subspace_dist(
        &crate::linalg::Mat::eye(2),
        &crate::linalg::Mat::eye(2),
    ); // keep the import exercised in quick mode
}

/// End-to-end serving benchmark (the required E2E driver's measurement
/// core): train, freeze, serve `total` requests through the batched
/// coordinator, report throughput and latency percentiles.
///
/// The load generator is open-loop pipelined: `workers * 64` requests are
/// kept in flight from one submitter thread. (Closed-loop blocking
/// clients on this single-core container measure scheduler ping-pong,
/// not the server — see EXPERIMENTS.md §Perf.)
pub fn serving_benchmark(
    engine: crate::coordinator::EngineSpec,
    total: usize,
    workers: usize,
) -> (f64, u64, u64, std::sync::Arc<crate::coordinator::metrics::Metrics>) {
    use crate::coordinator::{BatcherConfig, Server, ServingModel};
    use std::collections::VecDeque;
    let data = gen_stress_1d(10_000, 0.05, 8);
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 512)]);
    let cfg = MsgpConfig { n_per_dim: vec![512], ..Default::default() };
    let mut model = MsgpModel::fit_with_grid(kernel, 0.01, data, grid, cfg).unwrap();
    let serving = ServingModel::from_msgp(&mut model);
    let server = std::sync::Arc::new(Server::start(
        serving,
        engine,
        BatcherConfig { max_wait: Duration::from_millis(1), max_batch: 256, eager: true },
    ));
    let window = (workers * 64).max(64);
    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    let mut inflight = VecDeque::with_capacity(window);
    for _ in 0..total {
        if inflight.len() >= window {
            let rx: std::sync::mpsc::Receiver<anyhow::Result<crate::coordinator::Prediction>> =
                inflight.pop_front().unwrap();
            let p = rx.recv().unwrap().unwrap();
            assert!(p.mean.is_finite());
        }
        let x = rng.uniform_in(-10.0, 10.0);
        inflight.push_back(server.submit(vec![x]).unwrap());
    }
    for rx in inflight {
        let p = rx.recv().unwrap().unwrap();
        assert!(p.mean.is_finite());
    }
    let wall = t0.elapsed().as_secs_f64();
    let throughput = total as f64 / wall;
    let p50 = server.metrics.latency_quantile_us(0.5);
    let p99 = server.metrics.latency_quantile_us(0.99);
    let metrics = server.metrics.clone();
    (throughput, p50, p99, metrics)
}
