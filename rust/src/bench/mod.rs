//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (section 6 + appendix A.3). Each `fig*` function prints the
//! same rows/series the paper plots; `repro exp --fig N` and the cargo
//! bench targets call into here.
//!
//! Scale notes: the paper's absolute axes (up to n = 10^7 on a 2014
//! workstation MATLAB stack) are compressed to keep a full reproduction
//! run in CI-scale time; pass `--full` for the larger sweeps. The *shape*
//! of every comparison (who wins, crossovers, flatness in m) is the
//! reproduction target — see EXPERIMENTS.md.

pub mod experiments;
pub mod loadgen;
pub mod recorder;

pub use crate::util::timing::{bench_fn, bench_header, fmt_dur, BenchStats};
pub use recorder::{config_hash, Record, Recorder};
