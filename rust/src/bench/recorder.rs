//! Persistent benchmark artifact recording.
//!
//! Every `benches/fig*_*.rs` target writes its timings through a
//! [`Recorder`], which persists them as `BENCH_<figure>.json` in the
//! directory named by `MSGP_BENCH_DIR` (default: the working
//! directory). The file is an append-only map keyed by a free-form
//! config string, so re-running a bench **skips configs that are
//! already recorded** ([`Recorder::record_if_new`]) — the perf
//! trajectory across PRs accumulates instead of being overwritten.
//!
//! Entry shape (per config key):
//!
//! ```json
//! {
//!   "config": "m=4096 probes=8",
//!   "config_hash": "9e1c2f0a63b14d7b",
//!   "median_ns": 1234567, "mean_ns": 1300000,
//!   "min_ns": 1200000, "max_ns": 1500000, "iters": 11,
//!   "extra": {"mean_iters": 9.5}
//! }
//! ```
//!
//! `extra` carries bench-specific scalars (CG iteration counts, span
//! breakdowns, speedup ratios). Writes go through a tmp-file + rename
//! so a crashed bench never truncates the artifact; [`Recorder`] also
//! saves on `Drop`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::timing::BenchStats;

/// FNV-1a hash of a config string, hex-encoded — a stable short id for
/// cross-referencing configs between artifacts and logs.
pub fn config_hash(config: &str) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in config.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    format!("{h:016x}")
}

/// One recorded measurement.
#[derive(Clone, Debug)]
pub struct Record {
    /// Config key (free-form, e.g. `"m=4096 probes=8"`).
    pub config: String,
    /// Median / mean / min / max in nanoseconds and iteration count.
    pub median_ns: u64,
    /// Mean duration, nanoseconds.
    pub mean_ns: u64,
    /// Minimum duration, nanoseconds.
    pub min_ns: u64,
    /// Maximum duration, nanoseconds.
    pub max_ns: u64,
    /// Timed iterations behind the stats.
    pub iters: u64,
    /// Bench-specific scalars (span breakdowns, iteration counts, ...).
    pub extra: Vec<(String, f64)>,
}

impl Record {
    /// Build from [`BenchStats`] (name becomes the config key).
    pub fn from_stats(stats: &BenchStats) -> Record {
        Record {
            config: stats.name.clone(),
            median_ns: stats.median.as_nanos() as u64,
            mean_ns: stats.mean.as_nanos() as u64,
            min_ns: stats.min.as_nanos() as u64,
            max_ns: stats.max.as_nanos() as u64,
            iters: stats.iters as u64,
            extra: Vec::new(),
        }
    }

    /// Build from a single wall-clock measurement.
    pub fn from_duration(config: &str, wall: Duration) -> Record {
        let ns = wall.as_nanos() as u64;
        Record {
            config: config.to_string(),
            median_ns: ns,
            mean_ns: ns,
            min_ns: ns,
            max_ns: ns,
            iters: 1,
            extra: Vec::new(),
        }
    }

    /// Attach a bench-specific scalar.
    pub fn with_extra(mut self, key: &str, value: f64) -> Record {
        self.extra.push((key.to_string(), value));
        self
    }

    fn to_json(&self) -> Json {
        let extra = Json::Obj(
            self.extra.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
        );
        Json::obj(vec![
            ("config", Json::Str(self.config.clone())),
            ("config_hash", Json::Str(config_hash(&self.config))),
            ("median_ns", Json::Num(self.median_ns as f64)),
            ("mean_ns", Json::Num(self.mean_ns as f64)),
            ("min_ns", Json::Num(self.min_ns as f64)),
            ("max_ns", Json::Num(self.max_ns as f64)),
            ("iters", Json::Num(self.iters as f64)),
            ("extra", extra),
        ])
    }
}

/// Append-only per-figure benchmark artifact (`BENCH_<figure>.json`).
#[derive(Debug)]
pub struct Recorder {
    path: PathBuf,
    figure: String,
    entries: BTreeMap<String, Json>,
    dirty: bool,
}

impl Recorder {
    /// Open (or create) the artifact for `figure` — e.g. `"fig4"` maps
    /// to `BENCH_fig4.json` under `MSGP_BENCH_DIR` (default `.`).
    pub fn open(figure: &str) -> Recorder {
        let dir = std::env::var("MSGP_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        Recorder::open_in(Path::new(&dir), figure)
    }

    /// Open the artifact in an explicit directory (tests use this).
    pub fn open_in(dir: &Path, figure: &str) -> Recorder {
        let path = dir.join(format!("BENCH_{figure}.json"));
        let mut entries = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(Json::Obj(doc)) = Json::parse(&text) {
                if let Some(Json::Obj(existing)) = doc.get("entries") {
                    entries = existing.clone();
                }
            }
        }
        Recorder { path, figure: figure.to_string(), entries, dirty: false }
    }

    /// Artifact file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Is this config already recorded?
    pub fn has(&self, config: &str) -> bool {
        self.entries.contains_key(config)
    }

    /// Number of recorded configs.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is recorded yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert (or overwrite) a record.
    pub fn record(&mut self, rec: Record) {
        self.entries.insert(rec.config.clone(), rec.to_json());
        self.dirty = true;
    }

    /// The skip-if-already-recorded idiom: when `config` is present the
    /// (possibly expensive) measurement closure is not run at all.
    /// Returns `true` when the measurement ran.
    pub fn record_if_new(&mut self, config: &str, measure: impl FnOnce() -> Record) -> bool {
        if self.has(config) {
            return false;
        }
        let mut rec = measure();
        rec.config = config.to_string();
        self.record(rec);
        true
    }

    /// Persist to disk (tmp file + rename; also runs on drop).
    pub fn save(&mut self) -> std::io::Result<()> {
        if !self.dirty {
            return Ok(());
        }
        let doc = Json::obj(vec![
            ("figure", Json::Str(self.figure.clone())),
            ("format", Json::Num(1.0)),
            ("entries", Json::Obj(self.entries.clone())),
        ]);
        let tmp = self.path.with_extension("json.tmp");
        std::fs::write(&tmp, doc.to_string())?;
        std::fs::rename(&tmp, &self.path)?;
        self.dirty = false;
        Ok(())
    }
}

impl Drop for Recorder {
    fn drop(&mut self) {
        if self.dirty {
            if let Err(e) = self.save() {
                crate::log_warn!("bench recorder save failed for {:?}: {e}", self.path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("msgp_recorder_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_and_skip_idiom() {
        let dir = temp_dir("roundtrip");
        let mut r = Recorder::open_in(&dir, "test");
        assert!(r.is_empty());
        let ran = r.record_if_new("m=64", || {
            Record::from_duration("m=64", Duration::from_micros(250)).with_extra("iters", 7.0)
        });
        assert!(ran);
        r.save().unwrap();

        // Reopen: entry survives, closure is skipped.
        let mut r2 = Recorder::open_in(&dir, "test");
        assert!(r2.has("m=64"));
        assert_eq!(r2.len(), 1);
        let ran2 = r2.record_if_new("m=64", || panic!("must not re-measure"));
        assert!(!ran2);

        // Artifact is well-formed JSON with the expected fields.
        let text = std::fs::read_to_string(r2.path()).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("figure").and_then(|f| f.as_str()), Some("test"));
        let entry = doc.get("entries").and_then(|e| e.get("m=64")).unwrap();
        assert_eq!(entry.get("median_ns").and_then(|v| v.as_f64()), Some(250_000.0));
        assert_eq!(
            entry.get("config_hash").and_then(|v| v.as_str()),
            Some(config_hash("m=64").as_str())
        );
        let extra = entry.get("extra").and_then(|e| e.get("iters"));
        assert_eq!(extra.and_then(|v| v.as_f64()), Some(7.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_on_drop() {
        let dir = temp_dir("drop");
        {
            let mut r = Recorder::open_in(&dir, "drop");
            r.record(Record::from_duration("cfg", Duration::from_nanos(42)));
        }
        let r2 = Recorder::open_in(&dir, "drop");
        assert!(r2.has("cfg"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_hash_is_stable_fnv1a() {
        // FNV-1a reference value for the empty string is the offset
        // basis; a known vector pins the implementation.
        assert_eq!(config_hash(""), "cbf29ce484222325");
        assert_eq!(config_hash("a"), config_hash("a"));
        assert_ne!(config_hash("a"), config_hash("b"));
    }
}
