//! Reproducible load generation against the HTTP front door.
//!
//! The harness the perf trajectory is measured with (`fig9`): a
//! keep-alive [`HttpClient`], a multi-client [`run`] driver producing a
//! [`LoadReport`] with exact latency quantiles, and the CI [`smoke`]
//! sweep persisting `BENCH_fig9_serving.json` through
//! [`crate::bench::Recorder`].
//!
//! Two pacing modes:
//!
//! - **Closed loop** (`target_qps == 0`): each client fires its next
//!   request the moment the previous reply lands. Measures max
//!   sustained throughput; latency is response time.
//! - **Open loop** (`target_qps > 0`): requests are pre-scheduled on a
//!   fixed global cadence and latency is measured from the *scheduled*
//!   send time, so a stalled server accrues the queueing delay it
//!   caused instead of silently pausing the clock (no coordinated
//!   omission).

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::bench::recorder::{Record, Recorder};
use crate::coordinator::{BatcherConfig, HttpConfig, HttpServer, Server};
use crate::data::{gen_stress_1d, stress_fn};
use crate::gp::msgp::{KernelSpec, MsgpConfig};
use crate::grid::{Grid, GridAxis};
use crate::kernels::{KernelType, ProductKernel};
use crate::obs::Tracer;
use crate::shard::{ShardConfig, ShardedTrainer};
use crate::util::json::Json;
use crate::util::Rng;

/// A minimal keep-alive HTTP/1.1 client: one persistent connection,
/// lazily (re)connected, dropped on any I/O error or a
/// `Connection: close` response.
pub struct HttpClient {
    addr: SocketAddr,
    stream: Option<TcpStream>,
    timeout: Duration,
}

impl HttpClient {
    /// Client for `addr` with a 10 s I/O timeout.
    pub fn new(addr: SocketAddr) -> Self {
        HttpClient { addr, stream: None, timeout: Duration::from_secs(10) }
    }

    /// Issue one request and read the full framed response. Returns
    /// `(status, body)`. The connection is reused across calls unless
    /// the server asked to close or an error occurred.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> io::Result<(u16, String)> {
        let res = self.request_inner(method, path, body);
        if res.is_err() {
            self.stream = None;
        }
        res
    }

    fn request_inner(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> io::Result<(u16, String)> {
        if self.stream.is_none() {
            let s = TcpStream::connect(self.addr)?;
            s.set_read_timeout(Some(self.timeout))?;
            s.set_write_timeout(Some(self.timeout))?;
            s.set_nodelay(true)?;
            self.stream = Some(s);
        }
        let stream = self.stream.as_mut().expect("connected above");
        let b = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: msgp\r\nContent-Length: {}\r\n\r\n",
            b.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(b.as_bytes())?;
        stream.flush()?;
        let (status, close, payload) = read_response(stream)?;
        if close {
            self.stream = None;
        }
        Ok((status, payload))
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one `Content-Length`-framed response off `stream`:
/// `(status, connection-close, body)`.
fn read_response(stream: &mut TcpStream) -> io::Result<(u16, bool, String)> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let head_end = loop {
        if let Some(p) = find_subslice(&buf, b"\r\n\r\n") {
            break p;
        }
        let mut tmp = [0u8; 4096];
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof in response head"));
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let mut lines = head.split("\r\n");
    let status: u16 = lines
        .next()
        .unwrap_or("")
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad status line"))?;
    let mut len = 0usize;
    let mut close = false;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let v = v.trim();
            if k.eq_ignore_ascii_case("content-length") {
                len = v.parse().unwrap_or(0);
            } else if k.eq_ignore_ascii_case("connection") {
                close = v.eq_ignore_ascii_case("close");
            }
        }
    }
    let total = head_end + 4 + len;
    while buf.len() < total {
        let mut tmp = [0u8; 4096];
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof in response body"));
        }
        buf.extend_from_slice(&tmp[..n]);
    }
    let body = String::from_utf8_lossy(&buf[head_end + 4..total]).to_string();
    Ok((status, close, body))
}

/// Load-run shape: who sends what, how fast, against which address.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Front-door address.
    pub addr: SocketAddr,
    /// Concurrent client connections (one thread each).
    pub clients: usize,
    /// Requests issued per client.
    pub requests_per_client: usize,
    /// Open-loop target rate across all clients, requests/s
    /// (`0` = closed loop).
    pub target_qps: f64,
    /// Fraction of requests that are `/predict` reads (the rest are
    /// `/ingest` writes).
    pub read_frac: f64,
    /// Points per `/predict` request.
    pub predict_batch: usize,
    /// Observations per `/ingest` request.
    pub ingest_batch: usize,
    /// Input dimensionality.
    pub dim: usize,
    /// Coordinate range sampled uniformly per axis.
    pub lo: f64,
    /// Upper end of the coordinate range.
    pub hi: f64,
    /// RNG seed (each client derives its own stream from it).
    pub seed: u64,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            addr: SocketAddr::from(([127, 0, 0, 1], 0)),
            clients: 2,
            requests_per_client: 200,
            target_qps: 0.0,
            read_frac: 0.9,
            predict_batch: 8,
            ingest_batch: 16,
            dim: 1,
            lo: -10.0,
            hi: 11.0,
            seed: 7,
        }
    }
}

/// Outcome of one [`run`]: request counts and exact latency quantiles
/// (every request's latency is kept and sorted — no bucketing error).
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Requests issued (success + failure).
    pub requests: u64,
    /// Non-200 responses plus transport errors.
    pub errors: u64,
    /// `/predict` requests issued.
    pub predict_requests: u64,
    /// `/ingest` requests issued.
    pub ingest_requests: u64,
    /// Wall-clock of the whole run.
    pub elapsed: Duration,
    /// Sustained request throughput over the run.
    pub qps: f64,
    /// Per-request latencies, microseconds, ascending. Open-loop runs
    /// measure from the scheduled send time (coordinated-omission
    /// aware); closed-loop runs from the actual send.
    pub latencies_us: Vec<u64>,
}

impl LoadReport {
    /// Exact latency quantile (nearest-rank) in microseconds.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.latencies_us.len();
        if n == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as usize).clamp(1, n);
        self.latencies_us[rank - 1]
    }

    /// One human-readable line: counts, throughput, p50/p99/p999.
    pub fn summary_line(&self) -> String {
        format!(
            "requests={} (predict={} ingest={}) errors={} elapsed={:.2}s qps={:.0} \
             p50={}us p99={}us p999={}us",
            self.requests,
            self.predict_requests,
            self.ingest_requests,
            self.errors,
            self.elapsed.as_secs_f64(),
            self.qps,
            self.quantile_us(0.5),
            self.quantile_us(0.99),
            self.quantile_us(0.999),
        )
    }
}

/// Drive `cfg.clients` concurrent clients against `cfg.addr` and
/// collect every per-request latency.
pub fn run(cfg: &LoadConfig) -> LoadReport {
    let start = Instant::now();
    let interval = if cfg.target_qps > 0.0 {
        Some(Duration::from_secs_f64(1.0 / cfg.target_qps))
    } else {
        None
    };
    let per_client: Vec<ClientStats> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|t| s.spawn(move || client_loop(cfg, t, start, interval)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("loadgen client panicked")).collect()
    });
    let elapsed = start.elapsed();
    let mut latencies_us = Vec::new();
    let (mut errors, mut predicts, mut ingests) = (0u64, 0u64, 0u64);
    for c in per_client {
        latencies_us.extend(c.latencies_us);
        errors += c.errors;
        predicts += c.predicts;
        ingests += c.ingests;
    }
    latencies_us.sort_unstable();
    let requests = latencies_us.len() as u64;
    LoadReport {
        requests,
        errors,
        predict_requests: predicts,
        ingest_requests: ingests,
        elapsed,
        qps: requests as f64 / elapsed.as_secs_f64().max(1e-9),
        latencies_us,
    }
}

struct ClientStats {
    latencies_us: Vec<u64>,
    errors: u64,
    predicts: u64,
    ingests: u64,
}

fn client_loop(
    cfg: &LoadConfig,
    t: usize,
    start: Instant,
    interval: Option<Duration>,
) -> ClientStats {
    let mut rng = Rng::new(cfg.seed.wrapping_add(t as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let mut client = HttpClient::new(cfg.addr);
    let mut stats = ClientStats {
        latencies_us: Vec::with_capacity(cfg.requests_per_client),
        errors: 0,
        predicts: 0,
        ingests: 0,
    };
    for k in 0..cfg.requests_per_client {
        // Open loop: clients interleave on one global tick sequence.
        let scheduled = interval.map(|iv| start + iv * (k * cfg.clients + t) as u32);
        if let Some(at) = scheduled {
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
        }
        let read = rng.uniform() < cfg.read_frac;
        let (path, body) = if read {
            stats.predicts += 1;
            ("/predict", predict_body(cfg, &mut rng))
        } else {
            stats.ingests += 1;
            ("/ingest", ingest_body(cfg, &mut rng))
        };
        let t0 = Instant::now();
        let outcome = client.request("POST", path, Some(&body));
        let from = scheduled.unwrap_or(t0);
        let us = Instant::now().saturating_duration_since(from).as_micros() as u64;
        stats.latencies_us.push(us.max(1));
        match outcome {
            Ok((200, _)) => {}
            Ok(_) | Err(_) => stats.errors += 1,
        }
    }
    stats
}

fn predict_body(cfg: &LoadConfig, rng: &mut Rng) -> String {
    let pts = (0..cfg.predict_batch * cfg.dim)
        .map(|_| Json::Num(rng.uniform_in(cfg.lo, cfg.hi)))
        .collect();
    Json::obj(vec![("points", Json::Arr(pts))]).to_string()
}

fn ingest_body(cfg: &LoadConfig, rng: &mut Rng) -> String {
    let mut xs = Vec::with_capacity(cfg.ingest_batch * cfg.dim);
    let mut ys = Vec::with_capacity(cfg.ingest_batch);
    for _ in 0..cfg.ingest_batch {
        let x0 = rng.uniform_in(cfg.lo, cfg.hi);
        xs.push(Json::Num(x0));
        for _ in 1..cfg.dim {
            xs.push(Json::Num(rng.uniform_in(cfg.lo, cfg.hi)));
        }
        ys.push(Json::Num(stress_fn(x0) + 0.05 * rng.normal()));
    }
    Json::obj(vec![("xs", Json::Arr(xs)), ("ys", Json::Arr(ys))]).to_string()
}

/// Boot a sharded server behind a front door, run one fixed closed-loop
/// load (seeded, deterministic mix), tear down. Returns the report and
/// the load phase's wall-clock.
pub fn run_one_smoke(shards: usize, clients: usize, trace: bool) -> (LoadReport, Duration) {
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]);
    let cfg = ShardConfig {
        shards,
        refresh_every: 4096,
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() },
        ..Default::default()
    };
    let trainer = ShardedTrainer::start(kernel, 0.01, grid, cfg);
    let warm = gen_stress_1d(2000, 0.05, 3);
    trainer.ingest_batch(&warm.x, &warm.y);
    trainer.flush();
    let server = Arc::new(Server::start_sharded(trainer, BatcherConfig::default()));
    let http = HttpServer::bind(
        server.clone(),
        "127.0.0.1:0",
        HttpConfig { workers: clients.max(1), ..Default::default() },
    )
    .expect("bind loopback front door");
    Tracer::set_enabled(trace);
    let load = LoadConfig {
        addr: http.local_addr(),
        clients,
        requests_per_client: 400,
        ..Default::default()
    };
    let t0 = Instant::now();
    let report = run(&load);
    let wall = t0.elapsed();
    Tracer::set_enabled(false);
    http.shutdown();
    (report, wall)
}

/// The CI smoke sweep: two (shards, clients) closed-loop configs plus
/// an interleaved tracing-on/off overhead measurement, persisted as
/// `BENCH_fig9_serving.json` in `dir` (skip-if-recorded per config).
/// Returns the artifact path.
pub fn smoke(dir: &Path) -> anyhow::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let mut rec = Recorder::open_in(dir, "fig9_serving");
    for (shards, clients) in [(2usize, 2usize), (4, 4)] {
        let key = format!("smoke shards={shards} clients={clients} batch=8 read=0.9 mode=closed");
        rec.record_if_new(&key, || {
            let (report, wall) = run_one_smoke(shards, clients, false);
            crate::log_info!("fig9 {key}: {}", report.summary_line());
            Record::from_duration(&key, wall)
                .with_extra("shards", shards as f64)
                .with_extra("clients", clients as f64)
                .with_extra("requests", report.requests as f64)
                .with_extra("errors", report.errors as f64)
                .with_extra("qps", report.qps)
                .with_extra("p50_us", report.quantile_us(0.5) as f64)
                .with_extra("p99_us", report.quantile_us(0.99) as f64)
                .with_extra("p999_us", report.quantile_us(0.999) as f64)
        });
    }
    let key = "smoke trace_overhead shards=2 clients=2";
    rec.record_if_new(key, || {
        // Interleave off/on pairs and keep each mode's best, so drift
        // on a noisy CI box hits both modes symmetrically.
        let (mut qps_off, mut qps_on) = (0.0f64, 0.0f64);
        let mut wall = Duration::ZERO;
        for _ in 0..3 {
            let (off, w_off) = run_one_smoke(2, 2, false);
            let (on, w_on) = run_one_smoke(2, 2, true);
            qps_off = qps_off.max(off.qps);
            qps_on = qps_on.max(on.qps);
            wall += w_off + w_on;
        }
        Record::from_duration(key, wall)
            .with_extra("qps_off", qps_off)
            .with_extra("qps_on", qps_on)
            .with_extra("overhead_ratio_off_on", qps_off / qps_on.max(1e-9))
    });
    rec.save()?;
    Ok(rec.path().to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_are_nearest_rank_and_monotone() {
        let report = LoadReport {
            requests: 4,
            errors: 0,
            predict_requests: 4,
            ingest_requests: 0,
            elapsed: Duration::from_secs(1),
            qps: 4.0,
            latencies_us: vec![10, 20, 30, 1000],
        };
        assert_eq!(report.quantile_us(0.0), 10);
        assert_eq!(report.quantile_us(0.5), 20);
        assert_eq!(report.quantile_us(0.75), 30);
        assert_eq!(report.quantile_us(0.99), 1000);
        assert_eq!(report.quantile_us(1.0), 1000);
        let empty = LoadReport { latencies_us: Vec::new(), requests: 0, ..report };
        assert_eq!(empty.quantile_us(0.5), 0);
    }

    #[test]
    fn bodies_are_valid_json_with_the_configured_shapes() {
        let cfg = LoadConfig { predict_batch: 3, ingest_batch: 2, dim: 2, ..Default::default() };
        let mut rng = Rng::new(1);
        let p = Json::parse(&predict_body(&cfg, &mut rng)).expect("predict body parses");
        assert_eq!(p.get("points").and_then(|v| v.as_arr()).map(|a| a.len()), Some(6));
        let i = Json::parse(&ingest_body(&cfg, &mut rng)).expect("ingest body parses");
        assert_eq!(i.get("xs").and_then(|v| v.as_arr()).map(|a| a.len()), Some(4));
        assert_eq!(i.get("ys").and_then(|v| v.as_arr()).map(|a| a.len()), Some(2));
    }
}
