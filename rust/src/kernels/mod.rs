//! Covariance functions: SE (RBF), Matérn 1/2, 3/2, 5/2, and Rational
//! Quadratic — the `covSE`, `covMatern` and `covRQ` families benchmarked
//! in Figure 1 of the paper — with closed-form hyperparameter gradients.
//!
//! Two compositions are provided:
//!
//! * [`ProductKernel`] — a product across input dimensions (one stationary
//!   1-D kernel per dimension) scaled by a signal variance. This is what
//!   gives `K_{U,U}` its Kronecker-of-Toeplitz structure (Eq. 11).
//! * [`IsoKernel`] — an isotropic kernel of the Euclidean lag norm; it
//!   does *not* factorize, exercising the BTTB/BCCB path (section 5.3).

/// The stationary kernel families.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelType {
    /// Squared exponential `exp(-r^2 / (2 l^2))`.
    SE,
    /// Matérn nu = 1/2 (exponential) `exp(-r/l)`.
    Matern12,
    /// Matérn nu = 3/2.
    Matern32,
    /// Matérn nu = 5/2.
    Matern52,
    /// Rational quadratic `(1 + r^2/(2 a l^2))^{-a}` with fixed shape `a`.
    RQ {
        /// Shape parameter `alpha` (fixed, not learned).
        alpha_milli: u32,
    },
}

impl KernelType {
    /// RQ with shape `alpha` (stored in milli-units so the enum stays `Eq`-friendly).
    pub fn rq(alpha: f64) -> Self {
        KernelType::RQ { alpha_milli: (alpha * 1000.0).round() as u32 }
    }

    fn alpha(self) -> f64 {
        match self {
            KernelType::RQ { alpha_milli } => alpha_milli as f64 / 1000.0,
            _ => 0.0,
        }
    }

    /// Unit-variance correlation at distance `r >= 0` with lengthscale `ell`.
    pub fn corr(self, r: f64, ell: f64) -> f64 {
        let r = r.abs();
        match self {
            KernelType::SE => (-0.5 * (r / ell).powi(2)).exp(),
            KernelType::Matern12 => (-r / ell).exp(),
            KernelType::Matern32 => {
                let s = 3.0f64.sqrt() * r / ell;
                (1.0 + s) * (-s).exp()
            }
            KernelType::Matern52 => {
                let s = 5.0f64.sqrt() * r / ell;
                (1.0 + s + s * s / 3.0) * (-s).exp()
            }
            KernelType::RQ { .. } => {
                let a = self.alpha();
                (1.0 + r * r / (2.0 * a * ell * ell)).powf(-a)
            }
        }
    }

    /// Derivative of [`Self::corr`] with respect to `log ell`.
    pub fn dcorr_dlog_ell(self, r: f64, ell: f64) -> f64 {
        let r = r.abs();
        match self {
            KernelType::SE => {
                let q = (r / ell).powi(2);
                (-0.5 * q).exp() * q
            }
            KernelType::Matern12 => {
                let s = r / ell;
                (-s).exp() * s
            }
            KernelType::Matern32 => {
                let s = 3.0f64.sqrt() * r / ell;
                s * s * (-s).exp()
            }
            KernelType::Matern52 => {
                let s = 5.0f64.sqrt() * r / ell;
                (s * s * (1.0 + s) / 3.0) * (-s).exp()
            }
            KernelType::RQ { .. } => {
                let a = self.alpha();
                let q = r * r / (2.0 * a * ell * ell);
                let base = 1.0 + q;
                // d/dlog ell of base^{-a} = -a base^{-a-1} * dq/dlog ell, dq/dlog ell = -2q
                2.0 * a * q * base.powf(-a - 1.0)
            }
        }
    }

    /// Display name matching the paper's figure legends.
    pub fn name(self) -> String {
        match self {
            KernelType::SE => "covSE".into(),
            KernelType::Matern12 => "covMatern12".into(),
            KernelType::Matern32 => "covMatern32".into(),
            KernelType::Matern52 => "covMatern52".into(),
            KernelType::RQ { .. } => format!("covRQ(alpha={})", self.alpha()),
        }
    }
}

/// A product kernel across input dimensions with a shared signal variance:
/// `k(x, z) = sf2 * prod_d corr_d(|x_d - z_d|)`.
#[derive(Clone, Debug)]
pub struct ProductKernel {
    /// Per-dimension kernel family.
    pub types: Vec<KernelType>,
    /// Per-dimension log lengthscale.
    pub log_ell: Vec<f64>,
    /// Log signal variance.
    pub log_sf2: f64,
}

impl ProductKernel {
    /// Isotropic constructor: the same family and lengthscale in each of
    /// `d` dimensions.
    pub fn iso(ktype: KernelType, d: usize, ell: f64, sf2: f64) -> Self {
        ProductKernel {
            types: vec![ktype; d],
            log_ell: vec![ell.ln(); d],
            log_sf2: sf2.ln(),
        }
    }

    /// Input dimensionality.
    pub fn dim(&self) -> usize {
        self.types.len()
    }

    /// Signal variance.
    pub fn sf2(&self) -> f64 {
        self.log_sf2.exp()
    }

    /// Lengthscale of dimension `d`.
    pub fn ell(&self, d: usize) -> f64 {
        self.log_ell[d].exp()
    }

    /// Unit-variance correlation along dimension `d` at lag `r`.
    pub fn corr_d(&self, d: usize, r: f64) -> f64 {
        self.types[d].corr(r, self.ell(d))
    }

    /// Full kernel between two points.
    pub fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        let mut k = self.sf2();
        for d in 0..self.dim() {
            k *= self.corr_d(d, x[d] - z[d]);
        }
        k
    }

    /// Number of hyperparameters (`D` lengthscales + 1 signal variance).
    pub fn n_params(&self) -> usize {
        self.dim() + 1
    }

    /// Hyperparameters as a flat vector `[log_ell_0.., log_sf2]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.log_ell.clone();
        p.push(self.log_sf2);
        p
    }

    /// Set hyperparameters from a flat vector.
    pub fn set_params(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.n_params());
        let d = self.dim();
        self.log_ell.copy_from_slice(&p[..d]);
        self.log_sf2 = p[d];
    }
}

/// An isotropic (non-separable) kernel of the Euclidean lag:
/// `k(x, z) = sf2 * corr(||x - z||)`. Exercises the BTTB path.
#[derive(Clone, Debug)]
pub struct IsoKernel {
    /// Kernel family.
    pub ktype: KernelType,
    /// Log lengthscale.
    pub log_ell: f64,
    /// Log signal variance.
    pub log_sf2: f64,
}

impl IsoKernel {
    /// Construct from natural-scale parameters.
    pub fn new(ktype: KernelType, ell: f64, sf2: f64) -> Self {
        IsoKernel { ktype, log_ell: ell.ln(), log_sf2: sf2.ln() }
    }

    /// Evaluate at a lag vector.
    pub fn eval_lag(&self, lag: &[f64]) -> f64 {
        let r = lag.iter().map(|l| l * l).sum::<f64>().sqrt();
        self.log_sf2.exp() * self.ktype.corr(r, self.log_ell.exp())
    }

    /// Evaluate between two points.
    pub fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        let lag: Vec<f64> = x.iter().zip(z).map(|(a, b)| a - b).collect();
        self.eval_lag(&lag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TYPES: [KernelType; 5] = [
        KernelType::SE,
        KernelType::Matern12,
        KernelType::Matern32,
        KernelType::Matern52,
        KernelType::RQ { alpha_milli: 2000 },
    ];

    #[test]
    fn unit_variance_at_zero() {
        for t in TYPES {
            assert!((t.corr(0.0, 1.7) - 1.0).abs() < 1e-14, "{t:?}");
        }
    }

    #[test]
    fn monotone_decreasing() {
        for t in TYPES {
            let mut prev = 1.0;
            for i in 1..40 {
                let v = t.corr(i as f64 * 0.25, 2.0);
                assert!(v <= prev + 1e-14, "{t:?} at {i}");
                assert!(v >= 0.0);
                prev = v;
            }
        }
    }

    #[test]
    fn log_ell_gradient_matches_fd() {
        for t in TYPES {
            for &r in &[0.1, 0.7, 2.3, 5.0] {
                let ell: f64 = 1.3;
                let eps = 1e-6;
                let fp = t.corr(r, (ell.ln() + eps).exp());
                let fm = t.corr(r, (ell.ln() - eps).exp());
                let fd = (fp - fm) / (2.0 * eps);
                let an = t.dcorr_dlog_ell(r, ell);
                assert!((an - fd).abs() < 1e-7, "{t:?} r={r}: {an} vs {fd}");
            }
        }
    }

    #[test]
    fn product_kernel_eval_and_params() {
        let mut k = ProductKernel::iso(KernelType::SE, 2, 1.5, 2.0);
        let x = [0.0, 0.0];
        let z = [1.0, 2.0];
        let want = 2.0 * (-0.5 * (1.0f64 / 1.5).powi(2)).exp() * (-0.5 * (2.0f64 / 1.5).powi(2)).exp();
        assert!((k.eval(&x, &z) - want).abs() < 1e-12);
        let p = k.params();
        assert_eq!(p.len(), 3);
        k.set_params(&p);
        assert!((k.eval(&x, &z) - want).abs() < 1e-12);
    }

    #[test]
    fn iso_kernel_depends_only_on_norm() {
        let k = IsoKernel::new(KernelType::Matern32, 2.0, 1.0);
        let a = k.eval(&[0.0, 0.0], &[3.0, 4.0]);
        let b = k.eval(&[0.0, 0.0], &[5.0, 0.0]);
        assert!((a - b).abs() < 1e-14);
    }
}
