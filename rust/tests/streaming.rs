//! Streaming subsystem: property tests for the incremental sufficient
//! statistics, snapshot-swap consistency under concurrent readers, and
//! the end-to-end coordinator ingest -> refresh -> serve loop.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use msgp::coordinator::{BatcherConfig, EngineSpec, ModelSlot, Server, ServingModel};
use msgp::data::{gen_stress_1d, gen_stress_2d, Dataset};
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::grid::{Grid, GridAxis};
use msgp::interp::SparseInterp;
use msgp::kernels::{KernelType, ProductKernel};
use msgp::solver::Preconditioner;
use msgp::stream::{IncrementalSki, StreamConfig, StreamTrainer};
use msgp::util::Rng;

fn rmse(a: &[f64], b: &[f64]) -> f64 {
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

fn se_kernel() -> KernelSpec {
    KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0))
}

/// Satellite property: N single-point ingests reproduce the from-scratch
/// `W^T y` and per-cell counts to 1e-10.
#[test]
fn prop_incremental_wty_and_counts_match_batch_build() {
    for (n, seed) in [(57usize, 3u64), (400, 11), (201, 29)] {
        let data = gen_stress_1d(n, 0.1, seed);
        let grid = Grid::covering(&data.x, 1, &[96], 3);
        let mut ski = IncrementalSki::new(grid.clone(), 4, 3, seed);
        for i in 0..n {
            let exp = ski.ingest(&data.x[i..i + 1], data.y[i]);
            assert!(exp.is_none(), "covering grid must not expand");
        }
        assert_eq!(ski.n(), n);
        // From-scratch statistics.
        let w = SparseInterp::build(&data.x, &grid);
        let want_wty = w.tmatvec(&data.y);
        for (j, (a, b)) in ski.wty().iter().zip(&want_wty).enumerate() {
            assert!((a - b).abs() < 1e-10, "n={n} cell {j}: {a} vs {b}");
        }
        // Counts: every point lands in its nearest cell exactly once.
        let total: f64 = ski.counts().iter().sum();
        assert_eq!(total, n as f64);
        let mut want_counts = vec![0.0f64; grid.m()];
        for i in 0..n {
            let u = grid.axes[0].to_units(data.x[i]).round();
            let idx = (u.max(0.0) as usize).min(grid.axes[0].n - 1);
            want_counts[idx] += 1.0;
        }
        assert_eq!(ski.counts(), &want_counts[..]);
    }
}

/// The banded Gram accumulator agrees with the dense `W^T W`.
#[test]
fn prop_banded_gram_matches_dense_wtw_1d_and_2d() {
    // 1-D.
    let data = gen_stress_1d(150, 0.1, 7);
    let grid = Grid::covering(&data.x, 1, &[40], 3);
    let mut ski = IncrementalSki::new(grid.clone(), 2, 3, 7);
    ski.ingest_batch(&data.x, &data.y);
    let w = SparseInterp::build(&data.x, &grid);
    let mut rng = Rng::new(5);
    for _ in 0..5 {
        let v = rng.normal_vec(grid.m());
        let got = ski.g_matvec(&v);
        let want = w.tmatvec(&w.matvec(&v));
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
    // 2-D (exercises the multi-dimensional band encoding).
    let data2 = gen_stress_2d(120, 0.1, 9);
    let grid2 = Grid::covering(&data2.x, 2, &[14, 12], 3);
    let mut ski2 = IncrementalSki::new(grid2.clone(), 2, 3, 9);
    ski2.ingest_batch(&data2.x, &data2.y);
    let w2 = SparseInterp::build(&data2.x, &grid2);
    for _ in 0..5 {
        let v = rng.normal_vec(grid2.m());
        let got = ski2.g_matvec(&v);
        let want = w2.tmatvec(&w2.matvec(&v));
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
}

/// Grid auto-expansion preserves previously absorbed statistics exactly
/// (step-preserving whole-cell growth = pure index shift).
#[test]
fn prop_expansion_remaps_statistics_exactly() {
    let grid = Grid::new(vec![GridAxis::span(-2.0, 2.0, 32)]);
    let mut ski = IncrementalSki::new(grid, 3, 3, 13);
    let mut rng = Rng::new(21);
    // Phase 1: interior points (a handful suffices for the remap
    // property under Miri's interpreter).
    let n_interior = if cfg!(miri) { 12 } else { 60 };
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..n_interior {
        let x = rng.uniform_in(-1.5, 1.5);
        let y = rng.normal();
        xs.push(x);
        ys.push(y);
        ski.ingest(&[x], y);
    }
    // Phase 2: a far-out point forces expansion.
    let exp = ski.ingest(&[6.0], 0.5);
    assert!(exp.is_some(), "out-of-box point must expand the grid");
    xs.push(6.0);
    ys.push(0.5);
    let grid_now = ski.grid().clone();
    assert!(grid_now.covers(&[6.0], 1.0));
    // From-scratch build on the *final* grid must agree.
    let w = SparseInterp::build(&xs, &grid_now);
    let want_wty = w.tmatvec(&ys);
    for (a, b) in ski.wty().iter().zip(&want_wty) {
        assert!((a - b).abs() < 1e-10, "{a} vs {b}");
    }
    let v: Vec<f64> = (0..grid_now.m()).map(|i| ((i % 7) as f64) - 3.0).collect();
    let got = ski.g_matvec(&v);
    let want = w.tmatvec(&w.matvec(&v));
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }
}

/// The streaming m-domain mean solve reproduces batch-trained fast
/// predictions (same grid, same hypers) up to the Whittle-circulant
/// approximation.
#[test]
#[cfg_attr(miri, ignore = "full batch fit at m=256 is far beyond Miri's budget")]
fn streaming_refresh_matches_batch_predictions() {
    let data = gen_stress_1d(1500, 0.05, 17);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 256)]);
    let mcfg = MsgpConfig { n_per_dim: vec![256], n_var_samples: 8, ..Default::default() };
    let batch =
        MsgpModel::fit_with_grid(se_kernel(), 0.01, data.clone(), grid.clone(), mcfg.clone())
            .unwrap();
    let mut trainer = StreamTrainer::new(
        se_kernel(),
        0.01,
        grid,
        StreamConfig { msgp: mcfg, ..Default::default() },
    );
    trainer.ingest_batch(&data.x, &data.y);
    let stats = trainer.refresh();
    assert!(stats.mean_iters > 0 && stats.n == 1500);
    let sm = trainer.serving_model();
    let xs: Vec<f64> = (0..200).map(|i| -9.5 + i as f64 * 0.095).collect();
    let (stream_mean, _) = sm.predict_batch(&xs);
    let batch_mean = batch.predict_mean(&xs);
    let err = rmse(&stream_mean, &batch_mean);
    assert!(err < 0.02, "stream vs batch mean RMSE {err}");
}

/// Warm-started incremental refreshes converge in fewer CG iterations
/// than a from-zero refresh of the same state.
#[test]
fn warm_started_refresh_beats_cold_refresh() {
    let data = gen_stress_1d(2000, 0.05, 23);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 256)]);
    let mcfg = MsgpConfig { n_per_dim: vec![256], n_var_samples: 4, ..Default::default() };
    let cfg = StreamConfig { msgp: mcfg, ..Default::default() };
    let mut warm = StreamTrainer::new(se_kernel(), 0.01, grid.clone(), cfg.clone());
    // Absorb most of the stream and refresh (populates the warm starts).
    warm.ingest_batch(&data.x[..1800], &data.y[..1800]);
    warm.refresh();
    // Absorb a small increment and refresh again: warm path.
    warm.ingest_batch(&data.x[1800..], &data.y[1800..]);
    let warm_stats = warm.refresh();
    // Cold baseline: a fresh trainer over the identical data refreshes
    // from zero.
    let mut cold = StreamTrainer::new(se_kernel(), 0.01, grid, cfg);
    cold.ingest_batch(&data.x, &data.y);
    let cold_stats = cold.refresh();
    assert!(
        warm_stats.mean_iters < cold_stats.mean_iters,
        "warm {} !< cold {}",
        warm_stats.mean_iters,
        cold_stats.mean_iters
    );
}

/// Satellite property: concurrent `predict_batch` readers racing a
/// swapper never observe a torn model. Each installed model is
/// internally consistent (predicts mean == var == its tag); a torn
/// snapshot would mix tags.
#[test]
fn prop_snapshot_swap_never_tears_under_concurrent_readers() {
    let grid = Grid::new(vec![GridAxis::span(-1.0, 1.0, 16)]);
    let tagged = |c: f64| -> ServingModel {
        // kss = 0, nu_u = 0 -> var = sigma2 = c; u_mean = c (partition of
        // unity) -> mean = c at interior points.
        ServingModel::from_parts(grid.clone(), vec![c; 16], vec![0.0; 16], 0.0, c)
    };
    let slot = Arc::new(ModelSlot::new(tagged(1.0)));
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for t in 0..4 {
        let slot = slot.clone();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            let mut seen = [false, false];
            while !stop.load(Ordering::Relaxed) {
                let model = slot.get();
                let xs: Vec<f64> = (0..8).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
                let (means, vars) = model.predict_batch(&xs);
                for (m, v) in means.iter().zip(&vars) {
                    assert!((m - v).abs() < 1e-9, "torn snapshot: mean {m} var {v}");
                    let tag = *m;
                    assert!(
                        (tag - 1.0).abs() < 1e-9 || (tag - 2.0).abs() < 1e-9,
                        "unknown tag {tag}"
                    );
                    seen[if (tag - 1.0).abs() < 1e-9 { 0 } else { 1 }] = true;
                }
            }
            seen
        }));
    }
    // Miri explores interleavings per swap, so a few dozen suffice
    // there; natively, hammer the slot for real.
    let swaps = if cfg!(miri) { 64 } else { 2000 };
    for i in 0..swaps {
        slot.swap(tagged(if i % 2 == 0 { 2.0 } else { 1.0 }));
        if i % 64 == 0 {
            std::thread::yield_now();
        }
    }
    stop.store(true, Ordering::Relaxed);
    let mut seen_any = [false, false];
    for j in joins {
        let seen = j.join().unwrap();
        seen_any[0] |= seen[0];
        seen_any[1] |= seen[1];
    }
    // Readers actually observed both versions (the race was real).
    assert!(seen_any[0] && seen_any[1], "swap race never exercised both versions");
}

/// Acceptance: end-to-end streaming through the coordinator. Ingest
/// >= 10k points via the `/ingest` route in batches; held-out RMSE must
/// match a batch-trained MSGP on the full dataset within 5%, with O(1)
/// per-point predict latency.
#[test]
#[cfg_attr(miri, ignore = ">=10k-point end-to-end run is far beyond Miri's budget")]
fn e2e_coordinator_streaming_matches_batch_rmse() {
    let n = 12_000;
    let data = gen_stress_1d(n, 0.05, 1);
    let test = gen_stress_1d(500, 0.0, 99);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 256)]);
    let mcfg = MsgpConfig { n_per_dim: vec![256], n_var_samples: 8, ..Default::default() };
    // Batch reference on the full dataset.
    let batch =
        MsgpModel::fit_with_grid(se_kernel(), 0.01, data.clone(), grid.clone(), mcfg.clone())
            .unwrap();
    let batch_rmse = rmse(&batch.predict_mean(&test.x), &test.y);
    assert!(batch_rmse < 0.1, "batch reference unexpectedly poor: {batch_rmse}");
    // Streaming: same grid + hypers, fed through the coordinator.
    let trainer = StreamTrainer::new(
        se_kernel(),
        0.01,
        grid,
        StreamConfig {
            msgp: mcfg,
            refresh_every: 4096, // a few mid-stream swaps
            ..Default::default()
        },
    );
    let server = Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default());
    let bs = 500;
    for c in 0..(n / bs) {
        let lo = c * bs;
        let hi = lo + bs;
        let applied = server
            .ingest(data.x[lo..hi].to_vec(), data.y[lo..hi].to_vec())
            .expect("ingest");
        assert_eq!(applied, bs);
    }
    server.flush_stream().expect("flush");
    assert_eq!(
        server.metrics.ingested_points_total.load(Ordering::Relaxed),
        n as u64
    );
    assert!(server.metrics.refresh_count.load(Ordering::Relaxed) >= 2);
    // Held-out predictions through the predict route.
    let t0 = Instant::now();
    let mut preds = Vec::with_capacity(test.y.len());
    for i in 0..test.y.len() {
        preds.push(server.predict(vec![test.x[i]]).unwrap().mean);
    }
    let per_point = t0.elapsed() / test.y.len() as u32;
    let stream_rmse = rmse(&preds, &test.y);
    assert!(
        stream_rmse <= batch_rmse * 1.05 + 1e-4,
        "stream RMSE {stream_rmse} vs batch {batch_rmse}"
    );
    // O(1) serving: a sparse gather + queue round trip. 50ms/pt is a
    // generous sanity ceiling even on loaded CI machines.
    assert!(per_point.as_millis() < 50, "predict latency {per_point:?}/pt");
    server.shutdown();
}

/// Streaming with grid auto-expansion end to end: start on a grid that
/// covers almost none of the data and let ingestion grow it.
#[test]
fn streaming_grid_expansion_end_to_end() {
    let data = gen_stress_1d(1200, 0.05, 31);
    let tiny = Grid::new(vec![GridAxis::span(-0.5, 0.5, 16)]);
    let mcfg = MsgpConfig { n_per_dim: vec![16], n_var_samples: 4, ..Default::default() };
    let mut trainer = StreamTrainer::new(
        se_kernel(),
        0.01,
        tiny,
        StreamConfig { msgp: mcfg, ..Default::default() },
    );
    for c in 0..12 {
        let lo = c * 100;
        let hi = lo + 100;
        trainer.ingest_batch(&data.x[lo..hi], &data.y[lo..hi]);
    }
    assert!(trainer.m() > 16, "grid must have auto-expanded (m = {})", trainer.m());
    let covered = trainer.grid().covers(&[-10.0], 1.0) && trainer.grid().covers(&[10.0], 1.0);
    assert!(covered, "expanded grid must cover the data range");
    trainer.refresh();
    let sm = trainer.serving_model();
    let test = gen_stress_1d(300, 0.0, 77);
    let (mean, _) = sm.predict_batch(&test.x);
    let err = rmse(&mean, &test.y);
    // The expanded grid keeps the tiny grid's (coarse) step, so allow a
    // looser tolerance than the fixed-grid test.
    assert!(err < 0.2, "post-expansion RMSE {err}");
}

/// Hyperparameter re-optimization on the reservoir snapshot improves a
/// deliberately mis-specified kernel.
#[test]
fn reservoir_reopt_improves_misspecified_hypers() {
    let data = gen_stress_1d(1500, 0.05, 41);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]);
    let mcfg = MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() };
    // Start far from good hypers: tiny lengthscale, tiny signal.
    let bad = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 0.25, 0.3));
    let mut trainer = StreamTrainer::new(
        bad,
        0.2,
        grid,
        StreamConfig {
            msgp: mcfg,
            reopt_iters: 25,
            reopt_lr: 0.1,
            reservoir: 512,
            ..Default::default()
        },
    );
    trainer.ingest_batch(&data.x, &data.y);
    trainer.refresh();
    let test = gen_stress_1d(300, 0.0, 55);
    let before = {
        let sm = trainer.serving_model();
        rmse(&sm.predict_batch(&test.x).0, &test.y)
    };
    let lml = trainer.reoptimize().unwrap().expect("reservoir non-empty");
    assert!(lml.is_finite());
    let after = {
        let sm = trainer.serving_model();
        rmse(&sm.predict_batch(&test.x).0, &test.y)
    };
    assert!(after < before, "re-opt must improve held-out RMSE: {after} !< {before}");
}

/// Satellite: exponential forgetting. `decay(gamma)` scales every
/// linear accumulator by `gamma` (probes by `sqrt(gamma)`), leaves the
/// running target mean invariant, and lets fresh data overwrite stale
/// structure on a non-stationary stream.
#[test]
fn decay_downweights_history_exactly_and_tracks_regime_change() {
    // Exactness of the scaling itself.
    let data = gen_stress_1d(300, 0.1, 61);
    let grid = Grid::covering(&data.x, 1, &[64], 3);
    let mut ski = IncrementalSki::new(grid.clone(), 3, 3, 61);
    ski.ingest_batch(&data.x, &data.y);
    let wty0 = ski.wty().to_vec();
    let counts0 = ski.counts().to_vec();
    let probes0: Vec<Vec<f64>> = ski.probes().to_vec();
    let diag0 = ski.g_diag().to_vec();
    let mean0 = ski.y_mean();
    let gamma = 0.25f64;
    ski.decay(gamma);
    for (a, b) in ski.wty().iter().zip(&wty0) {
        assert!((a - gamma * b).abs() < 1e-12);
    }
    for (a, b) in ski.counts().iter().zip(&counts0) {
        assert!((a - gamma * b).abs() < 1e-12);
    }
    for (a, b) in ski.g_diag().iter().zip(&diag0) {
        assert!((a - gamma * b).abs() < 1e-12);
    }
    let root = gamma.sqrt();
    for (q, q0) in ski.probes().iter().zip(&probes0) {
        for (a, b) in q.iter().zip(q0) {
            assert!((a - root * b).abs() < 1e-12);
        }
    }
    assert!((ski.y_mean() - mean0).abs() < 1e-9, "y_mean must be decay-invariant");
    assert!((ski.weight() - gamma * 300.0).abs() < 1e-9);
    assert_eq!(ski.n(), 300, "n counts raw ingests");

    // Regime change: phase A says y = +2 on [-5, 5], then a hard decay
    // epoch and phase B says y = -2. Without forgetting the refreshed
    // mean would sit near the (weighted) average; with gamma = 0.02 the
    // stale regime carries ~2% of the mass and the model follows B.
    let grid2 = Grid::new(vec![GridAxis::span(-8.0, 8.0, 96)]);
    let mcfg = MsgpConfig { n_per_dim: vec![96], n_var_samples: 4, ..Default::default() };
    let mut trainer = StreamTrainer::new(
        se_kernel(),
        0.05,
        grid2,
        StreamConfig { msgp: mcfg, ..Default::default() },
    );
    let mut rng = Rng::new(5);
    let xs_a: Vec<f64> = (0..800).map(|_| rng.uniform_in(-5.0, 5.0)).collect();
    let ys_a = vec![2.0; 800];
    trainer.ingest_batch(&xs_a, &ys_a);
    trainer.refresh();
    let before = trainer.serving_model().predict_batch(&[0.5]).0[0];
    assert!((before - 2.0).abs() < 0.2, "phase A mean {before}");
    trainer.decay(0.02);
    let xs_b: Vec<f64> = (0..800).map(|_| rng.uniform_in(-5.0, 5.0)).collect();
    let ys_b = vec![-2.0; 800];
    trainer.ingest_batch(&xs_b, &ys_b);
    let after = trainer.serving_model().predict_batch(&[0.5]).0[0];
    assert!((after - (-2.0)).abs() < 0.3, "post-decay mean {after} must track phase B");
    // Without decay, the same two phases average out instead.
    let grid3 = Grid::new(vec![GridAxis::span(-8.0, 8.0, 96)]);
    let mcfg3 = MsgpConfig { n_per_dim: vec![96], n_var_samples: 4, ..Default::default() };
    let mut stale = StreamTrainer::new(
        se_kernel(),
        0.05,
        grid3,
        StreamConfig { msgp: mcfg3, ..Default::default() },
    );
    stale.ingest_batch(&xs_a, &ys_a);
    stale.ingest_batch(&xs_b, &ys_b);
    let avg = stale.serving_model().predict_batch(&[0.5]).0[0];
    assert!(avg.abs() < 0.5, "undecayed mean {avg} averages the regimes");
}

/// Satellite: the Jacobi preconditioner (built from the tracked
/// `diag(G)`) cuts mean-solve CG iterations on a spatially non-uniform
/// stream, where the Gram diagonal spans orders of magnitude, without
/// changing the solution.
#[test]
#[cfg_attr(miri, ignore = "4k-point preconditioner comparison is far beyond Miri's budget")]
fn jacobi_precondition_cuts_refresh_iterations() {
    // All the mass in one tenth of the domain: diag(B) varies from
    // sigma^2 (empty cells) to O(100) (dense cells).
    let mut rng = Rng::new(97);
    let n = 4000;
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let x = rng.uniform_in(-9.5, -7.5);
        xs.push(x);
        ys.push(msgp::data::stress_fn(x) + 0.05 * rng.normal());
    }
    let make = |precondition: Preconditioner| {
        let grid = Grid::new(vec![GridAxis::span(-10.0, 10.0, 256)]);
        let mut mcfg = MsgpConfig { n_per_dim: vec![256], n_var_samples: 4, ..Default::default() };
        mcfg.cg.precondition = precondition;
        mcfg.cg.tol = 1e-8;
        mcfg.cg.max_iter = 2000;
        StreamTrainer::new(se_kernel(), 0.01, grid, StreamConfig { msgp: mcfg, ..Default::default() })
    };
    let mut plain = make(Preconditioner::None);
    plain.ingest_batch(&xs, &ys);
    let plain_stats = plain.refresh();
    let mut pre = make(Preconditioner::Jacobi);
    pre.ingest_batch(&xs, &ys);
    let pre_stats = pre.refresh();
    assert!(
        pre_stats.mean_iters < plain_stats.mean_iters,
        "jacobi {} !< plain {}",
        pre_stats.mean_iters,
        plain_stats.mean_iters
    );
    // Both converged to the same caches.
    let probe: Vec<f64> = (0..40).map(|i| -9.4 + 0.045 * i as f64).collect();
    let (mp, _) = plain.serving_model().predict_batch(&probe);
    let (mj, _) = pre.serving_model().predict_batch(&probe);
    let err = rmse(&mp, &mj);
    assert!(err < 1e-3, "preconditioned solution drifted: {err}");
}

/// Acceptance (tentpole): on a spatially skewed stream, the spectral
/// BCCB preconditioner needs no more mean-solve CG iterations than
/// Jacobi, which needs no more than unpreconditioned CG — and all three
/// refreshes agree on the served predictions to 1e-8. The spectral
/// variant must also deliver a strict win over the unpreconditioned
/// solve (the multi-level circulant inverse collapses the spectral
/// spread a diagonal cannot touch).
#[test]
#[cfg_attr(miri, ignore = "three full refresh comparisons are far beyond Miri's budget")]
fn spectral_beats_jacobi_beats_plain_on_skewed_stream() {
    // Two-thirds of the mass in [-9.5, -6.5], the rest across the full
    // domain: diag(G) spans orders of magnitude while every region
    // keeps some coverage.
    let mut rng = Rng::new(101);
    let n = 1000;
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        // Strictly inside the one-cell expansion margin, so all three
        // trainers keep the identical 256-cell grid.
        let x = if i % 3 == 0 {
            rng.uniform_in(-9.8, 9.8)
        } else {
            rng.uniform_in(-9.5, -6.5)
        };
        xs.push(x);
        ys.push(msgp::data::stress_fn(x) + 0.05 * rng.normal());
    }
    let run = |precondition: Preconditioner| {
        let grid = Grid::new(vec![GridAxis::span(-10.0, 10.0, 256)]);
        let mut mcfg = MsgpConfig { n_per_dim: vec![256], n_var_samples: 4, ..Default::default() };
        mcfg.cg.precondition = precondition;
        mcfg.cg.tol = 1e-12;
        mcfg.cg.max_iter = 4000;
        let mut t = StreamTrainer::new(
            se_kernel(),
            0.25,
            grid,
            StreamConfig { msgp: mcfg, ..Default::default() },
        );
        t.ingest_batch(&xs, &ys);
        let stats = t.refresh();
        assert!(!stats.precond_fallback);
        let probe: Vec<f64> = (0..200).map(|i| -9.8 + 0.098 * i as f64).collect();
        let (mean, _) = t.serving_model().predict_batch(&probe);
        (stats, mean)
    };
    let (plain, m_plain) = run(Preconditioner::None);
    let (jacobi, m_jacobi) = run(Preconditioner::Jacobi);
    let (spectral, m_spectral) = run(Preconditioner::Spectral);
    assert!(
        spectral.mean_iters <= jacobi.mean_iters && jacobi.mean_iters <= plain.mean_iters,
        "iteration ordering violated: spectral {} jacobi {} plain {}",
        spectral.mean_iters,
        jacobi.mean_iters,
        plain.mean_iters
    );
    assert!(
        spectral.mean_iters < plain.mean_iters,
        "spectral {} must strictly beat plain {}",
        spectral.mean_iters,
        plain.mean_iters
    );
    // The probe solves carry the same operator: the totals must order
    // the same way.
    assert!(
        spectral.var_iters_total <= plain.var_iters_total,
        "spectral probes {} vs plain {}",
        spectral.var_iters_total,
        plain.var_iters_total
    );
    // All three converged to the same model.
    for (a, b) in m_spectral.iter().zip(&m_plain) {
        assert!((a - b).abs() < 1e-8, "spectral vs plain: {a} vs {b}");
    }
    for (a, b) in m_jacobi.iter().zip(&m_plain) {
        assert!((a - b).abs() < 1e-8, "jacobi vs plain: {a} vs {b}");
    }
}

/// Satellite regression: repeated decay with no fresh ingest drives the
/// effective mass through the floating-point floor; the weight-
/// normalized statistics must stay finite and hyper re-opt must skip
/// (returning `None`) instead of refitting against vanished statistics.
#[test]
#[cfg_attr(miri, ignore = "re-optimization epochs are far beyond Miri's budget")]
fn repeated_decay_floors_mass_and_skips_reopt() {
    let data = gen_stress_1d(400, 0.05, 71);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 64)]);
    let mcfg = MsgpConfig { n_per_dim: vec![64], n_var_samples: 2, ..Default::default() };
    let mut trainer = StreamTrainer::new(
        se_kernel(),
        0.05,
        grid,
        StreamConfig { msgp: mcfg, reopt_iters: 3, ..Default::default() },
    );
    trainer.ingest_batch(&data.x, &data.y);
    // Sanity: with mass present, re-opt runs.
    assert!(trainer.reoptimize().unwrap().is_some());
    // 5000 epochs of gamma = 0.5 drive weight below every subnormal
    // (400 * 0.5^5000), exercising exact underflow to 0.0.
    for _ in 0..5000 {
        trainer.decay(0.5);
    }
    let ski = trainer.ski();
    assert!(ski.weight() < msgp::stream::MIN_EFFECTIVE_MASS);
    assert!(ski.y_mean().is_finite() && ski.y_mean() == 0.0, "{}", ski.y_mean());
    assert!(ski.y_var().is_finite() && ski.y_var() == 0.0, "{}", ski.y_var());
    // The reservoir still holds raw points, but the model has forgotten
    // the stream: re-opt must skip rather than snapshot stale hypers.
    let (_, res_y) = trainer.reservoir_snapshot();
    assert!(!res_y.is_empty());
    assert!(trainer.reoptimize().unwrap().is_none());
    // The refresh itself stays finite and converges (the caches decay
    // to the prior): a solve stalling at the iteration cap is exactly
    // the pathology the mass floor rules out. With the statistics
    // underflowed to zero, B = sigma^2 I and every solve is near-
    // instant, so staying far under the cap is the binding check.
    let stats = trainer.refresh();
    let cap = trainer.cfg.msgp.cg.max_iter;
    assert!(stats.mean_iters < cap, "mean solve stalled: {} iters", stats.mean_iters);
    assert!(
        stats.var_iters_total < cap,
        "probe solves stalled: {} iters",
        stats.var_iters_total
    );
    let sm = trainer.serving_model();
    let (mean, var) = sm.predict_batch(&[0.0, 5.0]);
    assert!(mean.iter().all(|v| v.is_finite()));
    assert!(var.iter().all(|v| v.is_finite() && *v >= 0.0));
}

/// Admission control: non-finite values and wild outliers (whose
/// auto-expansion would exceed the grid-size cap) are rejected without
/// corrupting statistics or ballooning memory.
#[test]
fn outliers_and_nans_are_rejected_not_absorbed() {
    let grid = Grid::new(vec![GridAxis::span(-10.0, 10.0, 64)]);
    let mcfg = MsgpConfig { n_per_dim: vec![64], n_var_samples: 2, ..Default::default() };
    let mut trainer = StreamTrainer::new(
        se_kernel(),
        0.01,
        grid,
        StreamConfig { msgp: mcfg, max_grid_cells: 4096, ..Default::default() },
    );
    trainer.ingest_batch(&[0.5, f64::NAN, 1e9, -0.5, f64::INFINITY], &[1.0, 1.0, 1.0, 1.0, 1.0]);
    assert_eq!(trainer.n(), 2, "only the two sane points are absorbed");
    assert_eq!(trainer.rejected_points, 3);
    assert_eq!(trainer.m(), 64, "the 1e9 outlier must not explode the grid");
    // A moderate out-of-box point under the cap still expands normally.
    trainer.ingest_batch(&[15.0], &[0.2]);
    assert_eq!(trainer.rejected_points, 3);
    assert!(trainer.m() > 64 && trainer.m() < 4096);
    // The server front door rejects non-finite batches outright.
    let g2 = Grid::new(vec![GridAxis::span(-10.0, 10.0, 64)]);
    let mcfg2 = MsgpConfig { n_per_dim: vec![64], n_var_samples: 2, ..Default::default() };
    let t2 = StreamTrainer::new(
        se_kernel(),
        0.01,
        g2,
        StreamConfig { msgp: mcfg2, ..Default::default() },
    );
    let server = Server::start_online(t2, EngineSpec::Native, BatcherConfig::default());
    assert!(server.ingest(vec![f64::NAN], vec![1.0]).is_err());
    assert!(server.ingest(vec![0.0], vec![f64::NAN]).is_err());
    server.shutdown();
}

/// Ingest shape validation and the `Dataset` helper round trip.
#[test]
fn ingest_rejects_malformed_shapes() {
    let grid = Grid::new(vec![GridAxis::span(-1.0, 1.0, 16)]);
    let mcfg = MsgpConfig { n_per_dim: vec![16], n_var_samples: 2, ..Default::default() };
    let trainer = StreamTrainer::new(
        se_kernel(),
        0.01,
        grid,
        StreamConfig { msgp: mcfg, ..Default::default() },
    );
    let server = Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default());
    assert!(server.ingest(vec![0.0, 0.5], vec![1.0]).is_err(), "xs/ys mismatch");
    assert!(server.ingest(vec![0.0], vec![1.0]).is_ok());
    // Dataset sanity used across the suite.
    let d = Dataset { x: vec![1.0, 2.0], d: 1, y: vec![3.0, 4.0] };
    assert_eq!(d.n(), 2);
    server.shutdown();
}
