//! Sharded data-parallel subsystem: merge exactness against a
//! single-trainer build, seam continuity of blended serving, and the
//! end-to-end sharded coordinator.

use std::sync::atomic::Ordering;

use msgp::coordinator::{BatcherConfig, Server};
use msgp::data::{gen_stress_1d, gen_stress_2d, stress_fn};
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::shard::{ShardConfig, ShardPlan, ShardedTrainer};
use msgp::stream::{IncrementalSki, StreamConfig, StreamTrainer};
use msgp::util::Rng;

fn rmse(a: &[f64], b: &[f64]) -> f64 {
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

fn se_kernel(d: usize) -> KernelSpec {
    KernelSpec::Product(ProductKernel::iso(KernelType::SE, d, 1.0, 1.0))
}

/// Acceptance: S-shard merged sufficient statistics equal a
/// single-trainer build to 1e-10 on a random stream — including points
/// landing in the halos (the uniform stream hits every blend zone; halo
/// copies must not double count).
#[test]
fn merged_stats_match_single_trainer_1d() {
    let n = 3000;
    let mut rng = Rng::new(17);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let x = rng.uniform_in(-9.0, 9.0);
        xs.push(x);
        ys.push(stress_fn(x) + 0.1 * rng.normal());
    }
    let grid = Grid::new(vec![GridAxis::span(-10.0, 10.0, 128)]);
    let ns = 4;
    let cfg = ShardConfig {
        shards: 4,
        halo: 6,
        blend: 3,
        refresh_every: usize::MAX,
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: ns, ..Default::default() },
        ..Default::default()
    };
    let sharded = ShardedTrainer::start(se_kernel(1), 0.01, grid.clone(), cfg);
    // Feed in batches so the routing/ack path is exercised repeatedly.
    let mut applied = 0;
    for chunk in 0..10 {
        let lo = chunk * (n / 10);
        let hi = lo + n / 10;
        applied += sharded.ingest_batch(&xs[lo..hi], &ys[lo..hi]);
    }
    assert_eq!(applied, n, "interior points must all be admitted");
    let merged = sharded.merged_stats();
    // Single-trainer reference on the identical global grid.
    let mut single = IncrementalSki::new(grid.clone(), ns, 1, 999);
    single.ingest_batch(&xs, &ys);
    assert_eq!(merged.n(), single.n());
    assert!((merged.weight() - single.weight()).abs() < 1e-9);
    for (j, (a, b)) in merged.wty().iter().zip(single.wty()).enumerate() {
        assert!((a - b).abs() < 1e-10, "wty[{j}]: {a} vs {b}");
    }
    for (j, (a, b)) in merged.counts().iter().zip(single.counts()).enumerate() {
        assert!((a - b).abs() < 1e-9, "counts[{j}]: {a} vs {b}");
    }
    // Banded Gram: compare operator action on random vectors.
    let mut vrng = Rng::new(4242);
    for _ in 0..5 {
        let v = vrng.normal_vec(grid.m());
        let got = merged.g_matvec(&v);
        let want = single.g_matvec(&v);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
    // The combined global snapshot refreshes like a single trainer: its
    // mean cache reproduces an unsharded stream-trainer's predictions
    // (probe RNG differs, so variances are compared only for sanity).
    let mut merged_tr = sharded.merged_trainer();
    let mcfg = MsgpConfig { n_per_dim: vec![128], n_var_samples: ns, ..Default::default() };
    let mut solo = StreamTrainer::new(
        se_kernel(1),
        0.01,
        grid,
        StreamConfig { msgp: mcfg, ..Default::default() },
    );
    solo.ingest_batch(&xs, &ys);
    let probe: Vec<f64> = (0..100).map(|i| -8.5 + 0.17 * i as f64).collect();
    let (m_merged, v_merged) = merged_tr.serving_model().predict_batch(&probe);
    let (m_solo, _) = solo.serving_model().predict_batch(&probe);
    let err = rmse(&m_merged, &m_solo);
    assert!(err < 1e-3, "merged-trainer mean drifted from single trainer: {err}");
    assert!(v_merged.iter().all(|&v| v > 0.0 && v.is_finite()));
}

/// Merge exactness in 2-D: exercises the longest-axis selection and the
/// multi-dimensional band lift.
#[test]
fn merged_stats_match_single_trainer_2d() {
    let data = gen_stress_2d(900, 0.1, 23);
    let grid = Grid::covering(&data.x, 2, &[20, 12], 3);
    let ns = 3;
    let cfg = ShardConfig {
        shards: 2,
        halo: 4,
        blend: 2,
        refresh_every: usize::MAX,
        msgp: MsgpConfig {
            n_per_dim: grid.shape(),
            n_var_samples: ns,
            ..Default::default()
        },
        ..Default::default()
    };
    let sharded = ShardedTrainer::start(se_kernel(2), 0.05, grid.clone(), cfg);
    assert_eq!(sharded.plan().axis(), 0, "axis 0 has the most grid points");
    let applied = sharded.ingest_batch(&data.x, &data.y);
    assert_eq!(applied, data.y.len());
    let merged = sharded.merged_stats();
    let mut single = IncrementalSki::new(grid.clone(), ns, 1, 7);
    single.ingest_batch(&data.x, &data.y);
    for (a, b) in merged.wty().iter().zip(single.wty()) {
        assert!((a - b).abs() < 1e-10, "{a} vs {b}");
    }
    for (a, b) in merged.counts().iter().zip(single.counts()) {
        assert!((a - b).abs() < 1e-9);
    }
    let mut vrng = Rng::new(11);
    for _ in 0..3 {
        let v = vrng.normal_vec(grid.m());
        let got = merged.g_matvec(&v);
        let want = single.g_matvec(&v);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
}

/// Acceptance: sharded predictions are continuous at shard seams and
/// match the unsharded engine within tolerance across the whole domain
/// (the halo copies keep each local model accurate through its blend
/// zone).
#[test]
fn seam_continuity_matches_unsharded_engine() {
    let n = 6000;
    let data = gen_stress_1d(n, 0.05, 29);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 256)]);
    let mcfg = MsgpConfig { n_per_dim: vec![256], n_var_samples: 6, ..Default::default() };
    // Unsharded reference.
    let mut solo = StreamTrainer::new(
        se_kernel(1),
        0.01,
        grid.clone(),
        StreamConfig { msgp: mcfg.clone(), ..Default::default() },
    );
    solo.ingest_batch(&data.x, &data.y);
    let solo_model = solo.serving_model();
    // Sharded engine, 3 shards.
    let cfg = ShardConfig {
        shards: 3,
        halo: 8,
        blend: 4,
        refresh_every: usize::MAX,
        msgp: mcfg,
        ..Default::default()
    };
    let sharded = ShardedTrainer::start(se_kernel(1), 0.01, grid.clone(), cfg);
    sharded.ingest_batch(&data.x, &data.y);
    sharded.flush();
    // Whole-domain agreement.
    let sweep: Vec<f64> = (0..500).map(|i| -9.5 + 0.038 * i as f64).collect();
    let (sh_mean, sh_var) = sharded.predict_batch(&sweep);
    let (solo_mean, _) = solo_model.predict_batch(&sweep);
    let err = rmse(&sh_mean, &solo_mean);
    let max_diff = sh_mean
        .iter()
        .zip(&solo_mean)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(err < 0.05, "sharded vs unsharded RMSE {err}");
    assert!(max_diff < 0.1, "sharded vs unsharded max diff {max_diff}");
    assert!(sh_var.iter().all(|&v| v > 0.0 && v.is_finite()));
    // Fine sweep across each interior seam: no jumps. The posterior
    // mean's physical slope is O(1), so consecutive samples 0.005 units
    // apart must stay within a small step.
    let ax = &grid.axes[0];
    for s in 1..sharded.plan().shards() {
        let cut_x = ax.coord(sharded.plan().cuts()[s]);
        let fine: Vec<f64> = (0..400).map(|i| cut_x - 1.0 + 0.005 * i as f64).collect();
        let (fm, _) = sharded.predict_batch(&fine);
        for w in fm.windows(2) {
            assert!(
                (w[1] - w[0]).abs() < 0.05,
                "seam {s}: jump {} near x={cut_x}",
                (w[1] - w[0]).abs()
            );
        }
        // And the seam region agrees with the unsharded engine too.
        let (um, _) = solo_model.predict_batch(&fine);
        let seam_err = rmse(&fm, &um);
        assert!(seam_err < 0.05, "seam {s} RMSE vs unsharded: {seam_err}");
    }
}

/// End-to-end sharded coordinator: `/ingest` through the facade,
/// grouped prediction batches through the batcher, per-shard metrics,
/// `/shards` introspection, and admission control.
#[test]
fn e2e_sharded_server_learns_and_reports() {
    let n = 8000;
    let data = gen_stress_1d(n, 0.05, 3);
    let test = gen_stress_1d(300, 0.0, 91);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 256)]);
    let cfg = ShardConfig {
        shards: 2,
        halo: 6,
        blend: 3,
        refresh_every: 1024, // several automatic mid-stream publishes
        msgp: MsgpConfig { n_per_dim: vec![256], n_var_samples: 8, ..Default::default() },
        ..Default::default()
    };
    let trainer = ShardedTrainer::start(se_kernel(1), 0.01, grid, cfg);
    let server = Server::start_sharded(trainer, BatcherConfig::default());
    // Prior before any data.
    let prior = server.predict(vec![0.0]).unwrap();
    assert!(prior.mean.abs() < 1e-9 && prior.var > 0.9);
    let bs = 500;
    for c in 0..(n / bs) {
        let lo = c * bs;
        let hi = lo + bs;
        let applied = server
            .ingest(data.x[lo..hi].to_vec(), data.y[lo..hi].to_vec())
            .expect("ingest");
        assert_eq!(applied, bs);
    }
    server.flush_stream().expect("flush");
    // Held-out accuracy through the grouped prediction path.
    let mut preds = Vec::with_capacity(test.y.len());
    for i in 0..test.y.len() {
        preds.push(server.predict(vec![test.x[i]]).unwrap().mean);
    }
    let err = rmse(&preds, &test.y);
    assert!(err < 0.1, "sharded serving RMSE {err}");
    // Metrics: totals add up, every shard ingested and refreshed, and
    // predictions were routed per shard.
    let m = &server.metrics;
    assert_eq!(m.ingested_points_total.load(Ordering::Relaxed), n as u64);
    let per_shard: u64 = m.shards.iter().map(|s| s.ingested.load(Ordering::Relaxed)).sum();
    assert_eq!(per_shard, n as u64, "per-shard owned ingests must sum to the total");
    for (i, s) in m.shards.iter().enumerate() {
        assert!(s.ingested.load(Ordering::Relaxed) > 0, "shard {i} starved");
        assert!(s.refreshes.load(Ordering::Relaxed) >= 1, "shard {i} never refreshed");
        assert!(s.halo_ingested.load(Ordering::Relaxed) > 0, "shard {i} got no halo copies");
    }
    let routed: u64 = m.shards.iter().map(|s| s.routed_predictions.load(Ordering::Relaxed)).sum();
    assert_eq!(routed, 301, "every predict routed to exactly one owner");
    assert!(m.refresh_count.load(Ordering::Relaxed) >= 2);
    let summary = m.summary();
    assert!(summary.contains("shard[0]") && summary.contains("shard[1]"), "{summary}");
    // /shards introspection.
    let shards = server.shards_summary().expect("sharded server");
    assert!(shards.contains("shards=2") && shards.contains("owns="), "{shards}");
    // Admission: a finite point outside the fixed global box is
    // rejected per point (the sharded path never auto-expands).
    let applied = server.ingest(vec![1e9], vec![0.5]).unwrap();
    assert_eq!(applied, 0);
    assert!(m.ingest_rejected_total.load(Ordering::Relaxed) >= 1);
    // Non-finite batches still error at the front door.
    assert!(server.ingest(vec![f64::NAN], vec![1.0]).is_err());
    server.shutdown();
}

/// Sharded decay + whole-domain re-optimization: forgetting follows a
/// regime change across every shard, and the pooled-reservoir re-opt
/// improves deliberately mis-specified hypers on the global grid.
#[test]
fn sharded_decay_and_global_reopt() {
    // --- decay across shards ---
    let grid = Grid::new(vec![GridAxis::span(-8.0, 8.0, 96)]);
    let mcfg = MsgpConfig { n_per_dim: vec![96], n_var_samples: 4, ..Default::default() };
    let cfg = ShardConfig {
        shards: 2,
        halo: 5,
        blend: 2,
        refresh_every: usize::MAX,
        msgp: mcfg.clone(),
        ..Default::default()
    };
    let sharded = ShardedTrainer::start(se_kernel(1), 0.05, grid.clone(), cfg);
    let mut rng = Rng::new(13);
    let xs_a: Vec<f64> = (0..1200).map(|_| rng.uniform_in(-6.0, 6.0)).collect();
    let ys_a = vec![2.0; 1200];
    sharded.ingest_batch(&xs_a, &ys_a);
    sharded.flush();
    let before = sharded.predict_batch(&[0.25]).0[0];
    assert!((before - 2.0).abs() < 0.2, "phase A mean {before}");
    sharded.decay(0.02);
    let xs_b: Vec<f64> = (0..1200).map(|_| rng.uniform_in(-6.0, 6.0)).collect();
    let ys_b = vec![-2.0; 1200];
    sharded.ingest_batch(&xs_b, &ys_b);
    sharded.flush();
    // Probe right at the seam so both workers' decay matters.
    let seam_x = grid.axes[0].coord(sharded.plan().cuts()[1]);
    let (ms, _) = sharded.predict_batch(&[0.25, seam_x]);
    for m in ms {
        assert!((m - (-2.0)).abs() < 0.3, "post-decay mean {m} must track phase B");
    }
    // Merged stats carry the decayed weight.
    let merged = sharded.merged_stats();
    let want_w = 0.02 * 1200.0 + 1200.0;
    assert!((merged.weight() - want_w).abs() < 1e-6, "{} vs {want_w}", merged.weight());

    // --- whole-domain re-opt from pooled reservoirs ---
    let data = gen_stress_1d(1500, 0.05, 41);
    let test = gen_stress_1d(300, 0.0, 55);
    let bad = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 0.25, 0.3));
    let grid2 = Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]);
    let cfg2 = ShardConfig {
        shards: 2,
        halo: 5,
        blend: 2,
        refresh_every: usize::MAX,
        reservoir: 512,
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() },
    };
    let sh2 = ShardedTrainer::start(bad, 0.2, grid2, cfg2);
    sh2.ingest_batch(&data.x, &data.y);
    sh2.flush();
    let before = rmse(&sh2.predict_batch(&test.x).0, &test.y);
    let lml = sh2
        .reoptimize_global(25, 0.1)
        .unwrap()
        .expect("pooled reservoir non-empty");
    assert!(lml.is_finite());
    assert_eq!(sh2.metrics.reopt_count.load(Ordering::Relaxed), 1);
    let after = rmse(&sh2.predict_batch(&test.x).0, &test.y);
    assert!(after < before, "global re-opt must improve held-out RMSE: {after} !< {before}");
}

/// Satellite: pin the owner lookup for out-of-domain and seam points.
/// `ShardPlan::unit` clamps the split-axis coordinate into the box (and
/// a negative f64 saturates to 0 through `as usize` regardless), so a
/// point left of the domain must route to shard 0, a point right of the
/// domain to the last shard, and a point exactly on a cut to exactly
/// one owner (the shard whose half-open interval starts there).
#[test]
fn owner_lookup_saturates_out_of_domain_and_resolves_seams() {
    for (n, s) in [(101usize, 4usize), (97, 3), (128, 5)] {
        let grid = Grid::new(vec![GridAxis::span(0.0, (n - 1) as f64, n)]);
        let plan = ShardPlan::new(grid, s, 4, 2);
        // Left of the domain: negative coordinates saturate to shard 0.
        for x in [-0.5, -25.0, -1e12, f64::MIN] {
            assert_eq!(plan.owner_of(&[x]), 0, "left-of-domain x={x} (n={n}, s={s})");
        }
        // Right of the domain: clamps to the last cell -> last shard.
        for x in [(n - 1) as f64 + 0.5, 1e12, f64::MAX] {
            assert_eq!(
                plan.owner_of(&[x]),
                s - 1,
                "right-of-domain x={x} (n={n}, s={s})"
            );
        }
        // Interior seams: the cut belongs to the right-hand shard
        // (half-open ownership), and a point just left of it to the
        // left-hand shard — exactly one owner either way.
        for seam in 1..s {
            let cut = plan.cuts()[seam] as f64;
            assert_eq!(plan.owner_of(&[cut]), seam, "cut {seam} (n={n}, s={s})");
            assert_eq!(
                plan.owner_of(&[cut - 1e-9]),
                seam - 1,
                "just-left of cut {seam} (n={n}, s={s})"
            );
        }
    }
    // The split axis alone decides ownership: out-of-domain coordinates
    // on a non-split axis do not perturb the lookup.
    let grid2 = Grid::new(vec![GridAxis::span(0.0, 63.0, 64), GridAxis::span(0.0, 1.0, 6)]);
    let plan2 = ShardPlan::new(grid2, 2, 4, 2);
    assert_eq!(plan2.owner_of(&[-5.0, 99.0]), 0);
    assert_eq!(plan2.owner_of(&[99.0, -99.0]), 1);
}

/// Refresh-scaling smoke check (the full sweep lives in
/// `benches/fig5_sharded.rs`): per-shard refresh operates on m/S cells,
/// so each shard's local grid is a strict fraction of the global one.
#[test]
fn shard_plan_divides_refresh_work() {
    let grid = Grid::new(vec![GridAxis::span(0.0, 100.0, 1024)]);
    let plan = ShardPlan::new(grid.clone(), 4, 8, 4);
    let mtot: usize = (0..4).map(|s| plan.local_grid(s).m()).sum();
    // Local grids overlap only by the halos: sum m_local <= m + 2*halo*(S-1) + 2*halo.
    assert!(mtot <= grid.m() + 8 * 8);
    for s in 0..4 {
        let frac = plan.local_grid(s).m() as f64 / grid.m() as f64;
        assert!(frac < 0.30, "shard {s} covers {frac} of the grid");
    }
}
