//! Multi-node cluster chaos suite: three in-process nodes on loopback
//! TCP, driven through packet drops (failpoints), a peer kill, and a
//! restart-mid-stream — predictions must match a single-process merge
//! of the same stream to 1e-8 and must never hang.
//!
//! The failpoint registry is process-global, so every test serializes
//! on one static mutex (same discipline as `tests/robustness.rs`).

#![cfg(not(miri))] // thread/socket-heavy; far beyond Miri's budget

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use msgp::cluster::{ClusterConfig, ClusterNode};
use msgp::coordinator::http::{HttpConfig, HttpServer};
use msgp::coordinator::Server;
use msgp::data::gen_stress_1d;
use msgp::fault::{self, CkptConfig};
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::shard::{merge_owned, ShardPlan};
use msgp::stream::{IncrementalSki, StreamConfig, StreamTrainer};

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn se_kernel() -> KernelSpec {
    KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0))
}

fn stream_cfg() -> StreamConfig {
    StreamConfig {
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() },
        refresh_every: 1_000_000,
        ..Default::default()
    }
}

fn test_plan() -> ShardPlan {
    ShardPlan::new(Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]), 6, 4, 2)
}

/// Per-test scratch directory, removed on drop.
struct ScratchDir(PathBuf);

impl ScratchDir {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("msgp-cluster-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("create scratch dir");
        ScratchDir(dir)
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Tight timings so chaos tests converge in seconds, not minutes.
fn node_cfg(id: usize, peers: Vec<String>, ckpt_dir: Option<&PathBuf>) -> ClusterConfig {
    let mut cfg = ClusterConfig::new(id, peers);
    cfg.timeout = Duration::from_millis(500);
    cfg.ship_every = 48;
    cfg.ship_ms = 25;
    cfg.hb_ms = 50;
    cfg.ckpt = CkptConfig { dir: ckpt_dir.cloned(), every_points: 64, every_ms: 500 };
    cfg
}

/// Pre-bind ephemeral listeners so the membership table carries real
/// ports before any node starts, then start one node per listener.
fn start_cluster(n: usize, ckpt_dir: Option<&PathBuf>) -> (Vec<Arc<ClusterNode>>, Vec<String>) {
    let listeners: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind("127.0.0.1:0").expect("bind ephemeral")).collect();
    let peers: Vec<String> =
        listeners.iter().map(|l| l.local_addr().expect("local addr").to_string()).collect();
    let nodes: Vec<Arc<ClusterNode>> = listeners
        .into_iter()
        .enumerate()
        .map(|(id, l)| {
            let cfg = node_cfg(id, peers.clone(), ckpt_dir);
            ClusterNode::start(se_kernel(), 0.01, stream_cfg(), test_plan(), cfg, Some(l))
                .expect("start cluster node")
        })
        .collect();
    // Fresh nodes begin `recovering` until their first SyncDone, and
    // ingest is refused in that window — wait out the initial sync
    // before the tests drive traffic (real clients gate the same way,
    // see docs/CLUSTER.md).
    wait_for(
        || nodes.iter().all(|n| !n.recovering()),
        "initial cluster sync",
        Duration::from_secs(15),
    );
    (nodes, peers)
}

/// Feed one batch to every node; each keeps its stripe. Returns the
/// cluster-wide accepted count (each point lands on exactly one node).
fn fan_out(nodes: &[Arc<ClusterNode>], xs: &[f64], ys: &[f64]) -> usize {
    nodes.iter().map(|n| n.ingest(xs, ys).expect("node not recovering")).sum()
}

/// Points this node can see: its owned accumulators plus every replica.
fn total_points(node: &ClusterNode) -> usize {
    let j = node.cluster_summary();
    let count = |key: &str| -> f64 {
        j.get(key)
            .and_then(|v| v.as_arr())
            .map(|rows| rows.iter().filter_map(|r| r.get("n").and_then(|n| n.as_f64())).sum())
            .unwrap_or(0.0)
    };
    (count("owned") + count("replicas")) as usize
}

fn wait_for(mut cond: impl FnMut() -> bool, what: &str, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    panic!("timed out waiting for {what}");
}

/// The single-process parity reference: per-shard accumulators with the
/// cluster's exact seeds, each point ingested once into its owner,
/// merged over the global grid — the same statistics pipeline the
/// sharded engine uses for whole-domain snapshots.
fn reference_predict(xs: &[f64], ys: &[f64], probe: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let plan = test_plan();
    let scfg = stream_cfg();
    let ns = scfg.msgp.n_var_samples.max(1);
    let seed = scfg.msgp.seed;
    let mut parts: Vec<IncrementalSki> = (0..plan.shards())
        .map(|s| IncrementalSki::new(plan.local_grid(s), ns, 1, seed ^ (2 * s as u64)))
        .collect();
    for (i, &y) in ys.iter().enumerate() {
        let x = &xs[i..i + 1];
        parts[plan.owner_of(x)].ingest(x, y);
    }
    let merged = merge_owned(plan.global().clone(), seed, &parts);
    let mut trainer = StreamTrainer::from_stats(se_kernel(), 0.01, scfg, merged);
    trainer.serving_model().predict_batch(probe)
}

fn probe_points() -> Vec<f64> {
    (0..60).map(|i| -9.0 + 0.3 * i as f64).collect()
}

fn assert_parity(node: &ClusterNode, probe: &[f64], rm: &[f64], rv: &[f64], tag: &str) {
    for (i, &x) in probe.iter().enumerate() {
        let (m, v, _) = node.predict_one(&[x]);
        assert!(
            (m - rm[i]).abs() < 1e-8,
            "{tag}: node {} mean at x={x}: {m} vs {}",
            node.node_id(),
            rm[i]
        );
        assert!(
            (v - rv[i]).abs() < 1e-8,
            "{tag}: node {} var at x={x}: {v} vs {}",
            node.node_id(),
            rv[i]
        );
    }
}

/// An interior x whose owner shard is striped onto `node` (of `nodes`).
fn point_owned_by(node: usize, nodes: usize) -> f64 {
    let plan = test_plan();
    let mut x = -9.5;
    while x < 10.0 {
        if plan.node_of(plan.owner_of(&[x]), nodes) == node {
            return x;
        }
        x += 0.5;
    }
    panic!("no interior point owned by node {node}");
}

/// Happy path: three nodes each ingest their stripe of the stream,
/// deltas replicate, and every node's local merged model matches the
/// single-process reference to 1e-8 — with no staleness reported while
/// every peer is up.
#[test]
fn three_node_cluster_matches_single_process_merge() {
    let _g = guard();
    fault::clear_all();
    let data = gen_stress_1d(900, 0.05, 17);
    let (nodes, _) = start_cluster(3, None);
    let mut accepted = 0;
    for c in 0..9 {
        let lo = c * 100;
        accepted += fan_out(&nodes, &data.x[lo..lo + 100], &data.y[lo..lo + 100]);
    }
    assert_eq!(accepted, 900, "every point must land on exactly one node");
    for n in &nodes {
        n.flush();
    }
    wait_for(
        || nodes.iter().all(|n| total_points(n) == 900),
        "full replication on every node",
        Duration::from_secs(15),
    );
    for n in &nodes {
        n.flush(); // publish the final replica view synchronously
    }
    let probe = probe_points();
    let (rm, rv) = reference_predict(&data.x, &data.y, &probe);
    for node in &nodes {
        assert_parity(node, &probe, &rm, &rv, "steady state");
        let (_, _, stale) = node.predict_one(&[probe[0]]);
        assert!(stale.is_none(), "all peers up: no staleness bound expected");
    }
    for n in &nodes {
        n.shutdown();
    }
}

/// Packet-drop chaos: injected send/receive faults tear connections
/// mid-stream; every teardown reconnects with a full resync, so the
/// cluster still converges to exact parity once the faults clear.
#[test]
fn packet_drop_chaos_heals_via_reconnect_resync() {
    let _g = guard();
    fault::clear_all();
    let data = gen_stress_1d(600, 0.05, 29);
    let (nodes, _) = start_cluster(3, None);
    // ~20% of frame writes break the pipe, ~5% of receive polls drop
    // the connection — both indistinguishable from real network faults.
    fault::configure("peer.send=error@0.2; peer.recv=error@0.05").expect("valid spec");
    let mut accepted = 0;
    for c in 0..4 {
        let lo = c * 100;
        accepted += fan_out(&nodes, &data.x[lo..lo + 100], &data.y[lo..lo + 100]);
        std::thread::sleep(Duration::from_millis(30));
    }
    fault::clear_all();
    for c in 4..6 {
        let lo = c * 100;
        accepted += fan_out(&nodes, &data.x[lo..lo + 100], &data.y[lo..lo + 100]);
    }
    assert_eq!(accepted, 600);
    for n in &nodes {
        n.flush();
    }
    wait_for(
        || nodes.iter().all(|n| total_points(n) == 600),
        "post-chaos replication",
        Duration::from_secs(30),
    );
    for n in &nodes {
        n.flush();
    }
    let probe = probe_points();
    let (rm, rv) = reference_predict(&data.x, &data.y, &probe);
    for node in &nodes {
        assert_parity(node, &probe, &rm, &rv, "post packet-drop");
    }
    // The chaos must actually have bitten — and been repaired by full
    // resyncs beyond each connection's initial one.
    let send_errors: u64 = nodes
        .iter()
        .flat_map(|n| (0..3).map(move |p| n.metrics().peers[p].send_errors.get()))
        .sum();
    let full_syncs: u64 = nodes
        .iter()
        .flat_map(|n| (0..3).map(move |p| n.metrics().peers[p].full_syncs.get()))
        .sum();
    assert!(send_errors > 0, "injected faults must surface as send errors");
    assert!(full_syncs > 6, "repair requires resyncs beyond the 6 initial connections");
    for n in &nodes {
        n.shutdown();
    }
}

/// Kill one node mid-stream, keep serving (with a staleness bound for
/// its shards, and zero hangs), restart it on the same address, let it
/// restore its checkpoint + catch up over `SyncRequest`, re-send what
/// it missed, and finish the stream — full parity on all three nodes.
#[test]
fn peer_kill_restart_midstream_recovers_with_parity() {
    let _g = guard();
    fault::clear_all();
    let scratch = ScratchDir::new("restart");
    let data = gen_stress_1d(900, 0.05, 43);
    let (mut nodes, peers) = start_cluster(3, Some(&scratch.0));
    let mut accepted = 0;
    for c in 0..3 {
        let lo = c * 100;
        accepted += fan_out(&nodes, &data.x[lo..lo + 100], &data.y[lo..lo + 100]);
    }
    for n in &nodes {
        n.flush();
    }
    wait_for(
        || nodes.iter().all(|n| total_points(n) == 300),
        "segment A replication",
        Duration::from_secs(15),
    );
    // Kill node 2: threads stop, its listener closes, heartbeats cease.
    nodes[2].shutdown();
    wait_for(
        || nodes[0].peers_down() >= 1 && nodes[1].peers_down() >= 1,
        "heartbeat failure detection",
        Duration::from_secs(10),
    );
    // Survivors keep answering instantly — serving is always local. A
    // point owned by the dead node carries the staleness bound; a point
    // owned locally does not.
    let x_dead = point_owned_by(2, 3);
    let x_live = point_owned_by(0, 3);
    let (m, v, stale) = nodes[0].predict_one(&[x_dead]);
    assert!(m.is_finite() && v.is_finite());
    assert!(stale.is_some(), "owner down must report a staleness bound");
    assert!(nodes[0].predict_one(&[x_live]).2.is_none(), "own shard is never stale");
    // Segment B lands while node 2 is down: survivors keep their
    // stripes, node 2's stripe is lost until it returns.
    let survivors = [nodes[0].clone(), nodes[1].clone()];
    let mut seg_b = 0;
    for c in 3..6 {
        let lo = c * 100;
        seg_b += fan_out(&survivors, &data.x[lo..lo + 100], &data.y[lo..lo + 100]);
    }
    assert!(seg_b < 300, "the dead node's stripe must be missing from segment B");
    // Restart node 2 on its old address. Delay its outbound connects a
    // beat so the recovering window is deterministically observable.
    fault::configure("peer.connect=sleep(300)").expect("valid spec");
    let node2 = ClusterNode::start(
        se_kernel(),
        0.01,
        stream_cfg(),
        test_plan(),
        node_cfg(2, peers.clone(), Some(&scratch.0)),
        None, // re-binds peers[2] itself
    )
    .expect("rebind node 2 on its old address");
    assert!(node2.recovering(), "a restarted node must begin in recovery");
    // Ingest is refused until catch-up completes: points accepted now
    // would be silently overwritten by the adopted peer snapshot.
    assert!(
        node2.ingest(&data.x[300..301], &data.y[300..301]).is_err(),
        "a recovering node must refuse ingest, not silently lose points"
    );
    assert_eq!(
        node2.metrics().ckpt_restores_total.get(),
        1,
        "own checkpoint must restore before peer catch-up"
    );
    fault::clear_all();
    nodes[2] = node2;
    wait_for(|| !nodes[2].recovering(), "SyncRequest catch-up to complete", Duration::from_secs(15));
    wait_for(
        || nodes[0].peers_down() == 0 && nodes[1].peers_down() == 0,
        "liveness to recover",
        Duration::from_secs(10),
    );
    // Re-send the missed segment to the rejoined node only: it keeps
    // exactly its stripe, so nothing is double-counted cluster-wide.
    let missed =
        nodes[2].ingest(&data.x[300..600], &data.y[300..600]).expect("recovery has completed");
    assert_eq!(seg_b + missed, 300, "resend must recover exactly the lost stripe");
    accepted += seg_b + missed;
    for c in 6..9 {
        let lo = c * 100;
        accepted += fan_out(&nodes, &data.x[lo..lo + 100], &data.y[lo..lo + 100]);
    }
    assert_eq!(accepted, 900);
    for n in &nodes {
        n.flush();
    }
    wait_for(
        || nodes.iter().all(|n| total_points(n) == 900),
        "post-restart replication",
        Duration::from_secs(30),
    );
    for n in &nodes {
        n.flush();
    }
    let probe = probe_points();
    let (rm, rv) = reference_predict(&data.x, &data.y, &probe);
    for node in &nodes {
        assert_parity(node, &probe, &rm, &rv, "post restart");
    }
    assert!(nodes[0].predict_one(&[x_dead]).2.is_none(), "staleness clears once the owner is back");
    for n in &nodes {
        n.shutdown();
    }
}

fn raw_request(addr: &str, req: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect http front door");
    s.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    s.write_all(req.as_bytes()).expect("write request");
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    String::from_utf8_lossy(&out).into_owned()
}

fn raw_get(addr: &str, path: &str) -> String {
    raw_request(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"))
}

fn raw_post(addr: &str, path: &str, body: &str) -> String {
    raw_request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// The HTTP front door over a cluster node: `/cluster` and `/peers`
/// answer, `/predict` serves inline, and once a peer dies the response
/// grows an `X-Msgp-Staleness` header instead of hanging or erroring.
#[test]
fn http_front_door_reports_staleness_when_a_peer_dies() {
    let _g = guard();
    fault::clear_all();
    let data = gen_stress_1d(400, 0.05, 61);
    let (nodes, _) = start_cluster(2, None);
    let accepted = fan_out(&nodes, &data.x, &data.y);
    assert_eq!(accepted, 400);
    for n in &nodes {
        n.flush();
    }
    wait_for(
        || nodes.iter().all(|n| total_points(n) == 400),
        "two-node replication",
        Duration::from_secs(15),
    );
    let server = Arc::new(Server::start_cluster(nodes[0].clone()));
    let http = HttpServer::bind(server, "127.0.0.1:0", HttpConfig::default()).expect("bind http");
    let addr = http.local_addr().to_string();

    let resp = raw_get(&addr, "/cluster");
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("\"owned\"") && resp.contains("\"replicas\""), "{resp}");
    let resp = raw_get(&addr, "/peers");
    assert!(resp.contains("\"send_errors\""), "{resp}");

    let x_peer = point_owned_by(1, 2);
    let body = format!("{{\"points\": [{x_peer}]}}");
    let resp = raw_post(&addr, "/predict", &body);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(!resp.contains("X-Msgp-Staleness"), "peer alive: no staleness header: {resp}");

    nodes[1].shutdown();
    wait_for(|| nodes[0].peers_down() == 1, "peer death detection", Duration::from_secs(10));
    let resp = raw_post(&addr, "/predict", &body);
    assert!(resp.starts_with("HTTP/1.1 200"), "predict must answer, not hang: {resp}");
    assert!(resp.contains("X-Msgp-Staleness:"), "owner down: staleness header required: {resp}");
    let resp = raw_get(&addr, "/healthz");
    assert!(resp.contains("\"peers_down\""), "{resp}");

    http.shutdown();
    nodes[0].shutdown();
}
