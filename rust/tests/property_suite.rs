//! Property-style randomized sweeps over the numeric substrates
//! (the offline proptest substitute): each test draws many seeded random
//! instances and checks an exact mathematical invariant.

use msgp::grid::{Grid, GridAxis};
use msgp::interp::SparseInterp;
use msgp::kernels::KernelType;
use msgp::linalg::cholesky::Chol;
use msgp::linalg::fft::{dft_naive, fftn, plan};
use msgp::linalg::{C64, Mat};
use msgp::solver::{cg_solve, CgOptions, CgWorkspace};
use msgp::structure::bttb::{Bccb, Bttb};
use msgp::structure::circulant::{circulant_approx, Circulant, CirculantKind};
use msgp::structure::kronecker::{kron_dense, kron_matvec};
use msgp::structure::toeplitz::SymToeplitz;
use msgp::util::json::Json;
use msgp::util::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f64> {
    rng.normal_vec(n)
}

#[test]
fn prop_fft_roundtrip_many_sizes() {
    let mut rng = Rng::new(101);
    for trial in 0..60 {
        let n = 1 + rng.below(300);
        let p = plan(n);
        let x: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut y = x.clone();
        p.forward(&mut y);
        p.inverse(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((*a - *b).abs() < 1e-8 * (n as f64), "trial {trial} n {n}");
        }
    }
}

#[test]
fn prop_fft_linearity_and_parseval() {
    let mut rng = Rng::new(7);
    for _ in 0..20 {
        let n = 2 + rng.below(128);
        let p = plan(n);
        let x: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut fx = x.clone();
        p.forward(&mut fx);
        // Parseval: ||F x||^2 = n ||x||^2 (unnormalized forward DFT).
        let ex: f64 = x.iter().map(|z| z.norm_sqr()).sum();
        let ef: f64 = fx.iter().map(|z| z.norm_sqr()).sum();
        assert!((ef - n as f64 * ex).abs() < 1e-6 * (1.0 + ef), "n={n}");
    }
}

#[test]
fn prop_fft_matches_naive_on_random_sizes() {
    let mut rng = Rng::new(8);
    for _ in 0..15 {
        let n = 2 + rng.below(64);
        let x: Vec<C64> = (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut got = x.clone();
        plan(n).forward(&mut got);
        let want = dft_naive(&x, false);
        for (a, b) in got.iter().zip(&want) {
            assert!((*a - *b).abs() < 1e-7 * n as f64);
        }
    }
}

#[test]
fn prop_toeplitz_mvm_matches_dense_sweep() {
    let mut rng = Rng::new(21);
    for _ in 0..25 {
        let m = 2 + rng.below(80);
        let ell = 0.5 + rng.uniform() * 10.0;
        let kt = [KernelType::SE, KernelType::Matern32, KernelType::Matern12]
            [rng.below(3)];
        let col: Vec<f64> = (0..m).map(|i| kt.corr(i as f64, ell)).collect();
        let t = SymToeplitz::new(col.clone());
        let dense = Mat::from_fn(m, m, |i, j| col[i.abs_diff(j)]);
        let v = rand_vec(&mut rng, m);
        let got = t.matvec(&v);
        let want = dense.matvec(&v);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()));
        }
    }
}

#[test]
fn prop_circulant_solve_is_inverse_of_matvec() {
    let mut rng = Rng::new(33);
    for _ in 0..20 {
        let m = 4 + rng.below(200);
        let ell = 1.0 + rng.uniform() * 8.0;
        let col: Vec<f64> = (0..m)
            .map(|i| {
                let d = i.min(m - i) as f64;
                (-0.5 * (d / ell).powi(2)).exp()
            })
            .collect();
        let c = Circulant::new(col);
        let x = rand_vec(&mut rng, m);
        let jitter = 0.1 + rng.uniform();
        let y = {
            let mut v = c.matvec(&x);
            for (vi, xi) in v.iter_mut().zip(&x) {
                *vi += jitter * xi;
            }
            v
        };
        let back = c.solve(&y, jitter);
        for (a, b) in back.iter().zip(&x) {
            assert!((a - b).abs() < 1e-7 * (1.0 + b.abs()), "m={m}");
        }
    }
}

#[test]
fn prop_whittle_logdet_error_decays_with_m() {
    // Across kernels and lengthscales, doubling m from 256 to 1024 must
    // not increase the Whittle relative error, and at m = 1024 it is
    // below 1% (the paper's headline claim for the Whittle embedding).
    for kt in [KernelType::SE, KernelType::Matern32, KernelType::rq(2.0)] {
        for ell in [2.0, 8.0] {
            let err_at = |m: usize| -> f64 {
                let col: Vec<f64> = (0..m).map(|i| kt.corr(i as f64, ell)).collect();
                let t = SymToeplitz::new(col.clone());
                let exact = t.logdet_levinson(0.01).unwrap();
                let tail = |lag: usize| kt.corr(lag as f64, ell);
                let c = circulant_approx(CirculantKind::Whittle, &col, 3, Some(&tail));
                (c.logdet(0.01) - exact).abs() / exact.abs()
            };
            let e256 = err_at(256);
            let e1024 = err_at(1024);
            assert!(e1024 <= e256 * 1.5, "{kt:?} ell={ell}: {e256} -> {e1024}");
            assert!(e1024 < 0.01, "{kt:?} ell={ell}: err {e1024}");
        }
    }
}

#[test]
fn prop_kron_matvec_matches_dense_sweep() {
    let mut rng = Rng::new(55);
    for _ in 0..15 {
        let sizes = [2 + rng.below(4), 2 + rng.below(4), 1 + rng.below(3)];
        let factors: Vec<Mat> = sizes
            .iter()
            .map(|&s| {
                let b = Mat::from_vec(s, s, rng.normal_vec(s * s));
                let mut a = b.matmul(&b.t());
                for i in 0..s {
                    a[(i, i)] += 1.0;
                }
                a
            })
            .collect();
        let total: usize = sizes.iter().product();
        let v = rand_vec(&mut rng, total);
        let got = kron_matvec(&factors, &v);
        let want = kron_dense(&factors).matvec(&v);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8 * (1.0 + b.abs()));
        }
    }
}

#[test]
fn prop_bttb_matvec_matches_dense_random_kernels() {
    let mut rng = Rng::new(66);
    for trial in 0..10 {
        let shape = [2 + rng.below(5), 2 + rng.below(5)];
        let ell = 1.0 + rng.uniform() * 4.0;
        let anis = 0.5 + rng.uniform(); // anisotropic, non-separable
        let kfn = move |lag: &[f64]| -> f64 {
            let r = (lag[0] * lag[0] + anis * lag[1] * lag[1] + 0.3 * lag[0] * lag[1]).abs();
            (-r / (ell * ell)).exp()
        };
        let b = Bttb::new(&shape, &kfn);
        let m: usize = shape.iter().product();
        let unflat = |mut f: usize| -> [i64; 2] {
            let j = (f % shape[1]) as i64;
            f /= shape[1];
            [f as i64, j]
        };
        let dense = Mat::from_fn(m, m, |i, j| {
            let a = unflat(i);
            let c = unflat(j);
            kfn(&[(a[0] - c[0]) as f64, (a[1] - c[1]) as f64])
        });
        let v = rand_vec(&mut rng, m);
        let got = b.matvec(&v);
        let want = dense.matvec(&v);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-8 * (1.0 + y.abs()), "trial {trial}");
        }
    }
}

#[test]
fn prop_bccb_eigs_are_real_spectrum_of_dense() {
    let mut rng = Rng::new(77);
    for _ in 0..5 {
        let shape = [3 + rng.below(4), 3 + rng.below(4)];
        let ell = 2.0 + rng.uniform() * 3.0;
        let kfn = move |lag: &[f64]| -> f64 {
            let r2: f64 = lag.iter().map(|l| l * l).sum();
            (-0.5 * r2 / (ell * ell)).exp()
        };
        let b = Bccb::whittle(&shape, 1, &kfn);
        // Sum of eigenvalues = trace = m * c[0].
        let m: usize = shape.iter().product();
        let sum: f64 = b.eigs.iter().sum();
        // c[0] = sum over wraps of k at lag (j1*n1, j2*n2), j in {-1,0,1}.
        let mut c0 = 0.0;
        for j1 in -1i64..=1 {
            for j2 in -1i64..=1 {
                c0 += kfn(&[(j1 * shape[0] as i64) as f64, (j2 * shape[1] as i64) as f64]);
            }
        }
        assert!((sum - m as f64 * c0).abs() < 1e-6 * (1.0 + sum.abs()));
    }
}

#[test]
fn prop_interp_adjoint_identity_sweep() {
    let mut rng = Rng::new(88);
    for _ in 0..20 {
        let d = 1 + rng.below(2);
        let npd = 6 + rng.below(10);
        let axes: Vec<GridAxis> = (0..d).map(|_| GridAxis::span(-1.0, 1.0, npd)).collect();
        let grid = Grid::new(axes);
        let npts = 1 + rng.below(40);
        let pts: Vec<f64> = (0..npts * d).map(|_| rng.uniform_in(-0.8, 0.8)).collect();
        let w = SparseInterp::build(&pts, &grid);
        let u = rand_vec(&mut rng, grid.m());
        let v = rand_vec(&mut rng, npts);
        let lhs: f64 = w.matvec(&u).iter().zip(&v).map(|(a, b)| a * b).sum();
        let rhs: f64 = u.iter().zip(&w.tmatvec(&v)).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-10 * (1.0 + lhs.abs()));
    }
}

#[test]
fn prop_cg_matches_cholesky_on_random_spd() {
    let mut rng = Rng::new(99);
    for _ in 0..15 {
        let n = 3 + rng.below(40);
        let b = Mat::from_vec(n, n, rng.normal_vec(n * n));
        let mut a = b.matmul(&b.t());
        for i in 0..n {
            a[(i, i)] += n as f64 * 0.5;
        }
        let rhs = rand_vec(&mut rng, n);
        let want = Chol::new(&a).unwrap().solve(&rhs);
        let mut x = vec![0.0; n];
        let mut ws = CgWorkspace::new(n);
        let res = cg_solve(
            |v, out| out.copy_from_slice(&a.matvec(v)),
            |v, out| out.copy_from_slice(v),
            &rhs,
            &mut x,
            CgOptions { tol: 1e-12, max_iter: 10 * n, ..Default::default() },
            &mut ws,
        );
        assert!(res.converged);
        for (p, q) in x.iter().zip(&want) {
            assert!((p - q).abs() < 1e-7 * (1.0 + q.abs()));
        }
    }
}

#[test]
fn prop_kernel_gradients_match_fd_sweep() {
    let mut rng = Rng::new(111);
    let types = [
        KernelType::SE,
        KernelType::Matern12,
        KernelType::Matern32,
        KernelType::Matern52,
        KernelType::rq(1.0),
        KernelType::rq(3.5),
    ];
    for _ in 0..60 {
        let kt = types[rng.below(types.len())];
        let r = rng.uniform() * 8.0;
        let ell: f64 = 0.3 + rng.uniform() * 4.0;
        let eps = 1e-6;
        let fd = (kt.corr(r, (ell.ln() + eps).exp()) - kt.corr(r, (ell.ln() - eps).exp()))
            / (2.0 * eps);
        let an = kt.dcorr_dlog_ell(r, ell);
        assert!((an - fd).abs() < 1e-6 * (1.0 + fd.abs()), "{kt:?} r={r} ell={ell}");
    }
}

#[test]
fn prop_json_roundtrip_random_trees() {
    let mut rng = Rng::new(123);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => Json::Str(format!("s{}-\"x\"\n{}", rng.below(1000), rng.below(10))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for _ in 0..50 {
        let v = gen(&mut rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("parse {s}: {e}"));
        assert_eq!(v, back, "{s}");
    }
}

#[test]
fn prop_fftn_separable_equals_sequential_1d() {
    let mut rng = Rng::new(141);
    for _ in 0..8 {
        let shape = [2 + rng.below(4), 2 + rng.below(5)];
        let total = shape[0] * shape[1];
        let x: Vec<C64> = (0..total).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let mut got = x.clone();
        fftn(&mut got, &shape, false);
        // rows then columns with 1-D plans.
        let mut want = x;
        for r in 0..shape[0] {
            let mut row: Vec<C64> = want[r * shape[1]..(r + 1) * shape[1]].to_vec();
            plan(shape[1]).forward(&mut row);
            want[r * shape[1]..(r + 1) * shape[1]].copy_from_slice(&row);
        }
        for c in 0..shape[1] {
            let mut colv: Vec<C64> = (0..shape[0]).map(|r| want[r * shape[1] + c]).collect();
            plan(shape[0]).forward(&mut colv);
            for r in 0..shape[0] {
                want[r * shape[1] + c] = colv[r];
            }
        }
        for (a, b) in got.iter().zip(&want) {
            assert!((*a - *b).abs() < 1e-9);
        }
    }
}

#[test]
fn prop_levinson_matches_cholesky_sweep() {
    let mut rng = Rng::new(151);
    for _ in 0..15 {
        let m = 4 + rng.below(60);
        let ell = 0.5 + rng.uniform() * 6.0;
        let kt = [KernelType::SE, KernelType::Matern52][rng.below(2)];
        let col: Vec<f64> = (0..m).map(|i| kt.corr(i as f64, ell)).collect();
        let t = SymToeplitz::new(col);
        let s2 = 0.01 + rng.uniform();
        let lev = t.logdet_levinson(s2).unwrap();
        let chol = t.logdet_exact(s2).unwrap();
        assert!((lev - chol).abs() < 1e-7 * (1.0 + chol.abs()), "m={m}");
    }
}
