//! Integration: the AOT-compiled JAX/Pallas artifacts executed through
//! PJRT must agree with the native Rust engine on the same inputs —
//! the three-layer stack composing end to end.
//!
//! Requires `make artifacts`; tests are skipped (pass trivially) when the
//! artifact directory is missing so `cargo test` works standalone.

use msgp::coordinator::ServingModel;
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::runtime::Runtime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn serving_model_m512() -> ServingModel {
    let data = gen_stress_1d(2000, 0.05, 17);
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 512)]);
    let cfg = MsgpConfig { n_per_dim: vec![512], n_var_samples: 10, ..Default::default() };
    let mut model = MsgpModel::fit_with_grid(kernel, 0.01, data, grid, cfg).unwrap();
    ServingModel::from_msgp(&mut model)
}

#[test]
fn manifest_loads_and_compiles_all_artifacts() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let rt = Runtime::load(&dir).expect("runtime loads");
    assert!(rt.len() >= 10, "expected >= 10 artifacts, got {}", rt.len());
    assert!(!rt.by_kind("predict_meanvar", 1).is_empty());
    assert!(!rt.by_kind("predict_meanvar", 2).is_empty());
}

#[test]
fn pjrt_predictions_match_native_engine() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let sm = serving_model_m512();
    for bucket in [8usize, 32, 128, 256] {
        let name = format!("predict_meanvar_1d_b{bucket}");
        let xs: Vec<f64> = (0..bucket).map(|i| -9.0 + 18.0 * i as f64 / bucket as f64).collect();
        let units = sm.to_grid_units_f32(&xs);
        let (um, nu) = sm.grid_vecs_f32();
        let (mean, var) = rt
            .predict_meanvar(&name, &units, &um, &nu, sm.kss as f32, sm.sigma2 as f32)
            .unwrap();
        let (wm, wv) = sm.predict_batch(&xs);
        for i in 0..bucket {
            assert!(
                (mean[i] as f64 - wm[i]).abs() < 2e-4,
                "{name} mean[{i}]: {} vs {}",
                mean[i],
                wm[i]
            );
            assert!(
                (var[i] as f64 - wv[i]).abs() < 2e-4,
                "{name} var[{i}]: {} vs {}",
                var[i],
                wv[i]
            );
        }
    }
}

#[test]
fn pjrt_whittle_logdet_matches_rust_circulant() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let m = 512usize;
    // Wrapped SE column (symmetric circulant).
    let col: Vec<f64> = (0..m)
        .map(|i| {
            let d = i.min(m - i) as f64;
            (-0.5 * (d / 25.0).powi(2)).exp()
        })
        .collect();
    let col32: Vec<f32> = col.iter().map(|&v| v as f32).collect();
    let got = rt.whittle_logdet("whittle_logdet_m512", &col32, 0.1).unwrap() as f64;
    let want = msgp::structure::circulant::Circulant::new(col).logdet(0.1);
    assert!(
        (got - want).abs() < 1e-2 * (1.0 + want.abs()),
        "{got} vs {want}"
    );
}

#[test]
fn pjrt_kski_matvec_matches_rust_engine() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let (n, m, a) = (64usize, 32usize, 64usize);
    // Build the same operator in Rust: grid = unit steps 0..m.
    let data = {
        let mut rng = msgp::util::Rng::new(5);
        let x: Vec<f64> = (0..n).map(|_| rng.uniform_in(2.0, m as f64 - 3.0)).collect();
        msgp::data::Dataset { x, d: 1, y: vec![0.0; n] }
    };
    let kernel = ProductKernel::iso(KernelType::SE, 1, 3.0, 1.2);
    let grid = Grid::new(vec![GridAxis::span(0.0, (m - 1) as f64, m)]);
    let model = MsgpModel::fit_with_grid(
        KernelSpec::Product(kernel.clone()),
        0.07,
        data.clone(),
        grid,
        MsgpConfig { n_per_dim: vec![m], ..Default::default() },
    )
    .unwrap();
    let mut rng = msgp::util::Rng::new(7);
    let v: Vec<f64> = rng.normal_vec(n);
    let want = model.mvm_a(&v);
    // PJRT side: embedding column of sf2 * K_UU.
    let mut embed = vec![0.0f32; a];
    for i in 0..m {
        let k = 1.2 * (-0.5 * (i as f64 / 3.0).powi(2)).exp();
        embed[i] = k as f32;
        if i > 0 {
            embed[a - i] = k as f32;
        }
    }
    let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    let pts32: Vec<f32> = data.x.iter().map(|&x| x as f32).collect();
    let got = rt
        .kski_matvec("kski_matvec_1d_n64_m32", &v32, &pts32, &embed, 0.07)
        .unwrap();
    for i in 0..n {
        assert!(
            (got[i] as f64 - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()),
            "[{i}]: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn coordinator_uses_pjrt_backend_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use msgp::coordinator::{BatcherConfig, EngineSpec, Server};
    let sm = serving_model_m512();
    let direct = sm.predict_batch(&[0.5]);
    let server = Server::start(
        sm,
        EngineSpec::Pjrt(dir),
        BatcherConfig::default(),
    );
    let p = server.predict(vec![0.5]).unwrap();
    assert!((p.mean - direct.0[0]).abs() < 2e-4, "{} vs {}", p.mean, direct.0[0]);
    assert!((p.var - direct.1[0]).abs() < 2e-4);
    // The batch of 1 pads to bucket 8 and must run on PJRT.
    assert!(
        server.metrics.pjrt_batches.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "expected PJRT batches; metrics: {}",
        server.metrics.summary()
    );
    server.shutdown();
}
