//! Observability subsystem, end to end: concurrent metric hammering
//! against the Prometheus renderer, the tracer's refresh-span
//! decomposition through a live online server, the bench artifact
//! recorder, and the in-process route dispatch (`/metrics?format=prom`,
//! `/healthz`, `/trace`) the CI smoke job drives.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use msgp::bench::{config_hash, Record, Recorder};
use msgp::coordinator::{BatcherConfig, EngineSpec, Metrics, Server, ServingModel};
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::obs::Tracer;
use msgp::stream::{StreamConfig, StreamTrainer};
use msgp::util::json::Json;

fn se_kernel() -> KernelSpec {
    KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0))
}

fn serving_model() -> ServingModel {
    let data = gen_stress_1d(150, 0.05, 9);
    let cfg = MsgpConfig { n_per_dim: vec![96], n_var_samples: 6, ..Default::default() };
    let mut model = MsgpModel::fit(se_kernel(), 0.01, data, cfg).unwrap();
    ServingModel::from_msgp(&mut model)
}

/// Parse the cumulative buckets of `family` out of a Prometheus text
/// rendering: `(le, count)` pairs in exposition order.
fn buckets_of(prom: &str, family: &str) -> Vec<(String, u64)> {
    let prefix = format!("{family}_bucket{{le=\"");
    prom.lines()
        .filter_map(|l| {
            let rest = l.strip_prefix(&prefix)?;
            let (le, tail) = rest.split_once("\"}")?;
            Some((le.to_string(), tail.trim().parse::<u64>().ok()?))
        })
        .collect()
}

fn sample_of(prom: &str, name: &str) -> Option<u64> {
    prom.lines().find_map(|l| {
        let rest = l.strip_prefix(name)?;
        rest.strip_prefix(' ')?.trim().parse::<u64>().ok()
    })
}

/// Satellite (d): hammer counters and the latency histogram from N
/// threads while another thread drains the Prometheus rendering, then
/// assert exact totals and text-format validity on the final scrape.
#[test]
fn concurrent_hammer_preserves_exact_totals_and_prom_validity() {
    const THREADS: usize = 8;
    // Miri explores the same interleavings at a fraction of the iteration
    // count; keep the native run a real hammer.
    let per_thread: u64 = if cfg!(miri) { 100 } else { 10_000 };
    let latencies: u64 = if cfg!(miri) { 20 } else { 1_000 };
    let metrics = Arc::new(Metrics::with_shards(2));
    let stop = Arc::new(AtomicBool::new(false));

    // Scraper: every rendering mid-hammer must already be valid text.
    let scraper = {
        let m = metrics.clone();
        let stop = stop.clone();
        thread::spawn(move || {
            let mut scrapes = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let prom = m.render_prometheus();
                for line in prom.lines() {
                    if line.starts_with('#') || line.is_empty() {
                        continue;
                    }
                    let (_, value) = line.rsplit_once(' ').expect("sample line");
                    value.parse::<f64>().unwrap_or_else(|_| {
                        panic!("non-numeric sample value in {line:?}")
                    });
                }
                // Cumulative buckets must be monotone in every scrape,
                // not just the final quiescent one.
                let buckets = buckets_of(&prom, "request_latency_us");
                assert!(!buckets.is_empty());
                assert_eq!(buckets.last().unwrap().0, "+Inf");
                for w in buckets.windows(2) {
                    assert!(w[0].1 <= w[1].1, "non-monotone buckets: {w:?}");
                }
                scrapes += 1;
            }
            scrapes
        })
    };

    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let m = metrics.clone();
            thread::spawn(move || {
                for i in 0..per_thread {
                    m.submitted.fetch_add(1, Ordering::Relaxed);
                    m.completed.inc();
                    m.shards[t % 2].ingested.fetch_add(1, Ordering::Relaxed);
                    if i < latencies {
                        m.record_latency(Duration::from_micros(5));
                    }
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().unwrap();
    assert!(scrapes > 0, "scraper never ran");

    let total = THREADS as u64 * per_thread;
    let prom = metrics.render_prometheus();
    assert_eq!(sample_of(&prom, "submitted"), Some(total));
    assert_eq!(sample_of(&prom, "completed"), Some(total));
    assert_eq!(sample_of(&prom, "shard_ingested{shard=\"0\"}"), Some(total / 2));
    assert_eq!(sample_of(&prom, "shard_ingested{shard=\"1\"}"), Some(total / 2));
    let n_lat = THREADS as u64 * latencies;
    assert_eq!(sample_of(&prom, "request_latency_us_count"), Some(n_lat));
    assert_eq!(sample_of(&prom, "request_latency_us_sum"), Some(5 * n_lat));
    let buckets = buckets_of(&prom, "request_latency_us");
    assert_eq!(buckets.last().unwrap().1, n_lat, "+Inf bucket == count");
    // 5us lands in the (4, 8] bucket: everything at le >= 8 sees it.
    for (le, count) in &buckets {
        if let Ok(edge) = le.parse::<u64>() {
            assert_eq!(*count, if edge >= 8 { n_lat } else { 0 }, "le={le}");
        }
    }
    // The legacy one-line summary coexists with the same totals.
    let summary = metrics.summary();
    assert!(summary.contains(&format!("submitted={total}")), "{summary}");
}

/// Tentpole acceptance: with tracing enabled, a full ingest -> refresh
/// -> predict cycle produces a Chrome-trace JSON whose `refresh` span
/// decomposes into the stage-RHS / block-solve / map-back / slot-swap
/// child spans (time-contained, same thread).
#[test]
#[cfg_attr(miri, ignore = "full server + FFT refresh cycle is far beyond Miri's budget")]
fn trace_json_decomposes_refresh_into_stage_spans() {
    Tracer::clear();
    Tracer::set_enabled(true);
    let data = gen_stress_1d(400, 0.05, 21);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 64)]);
    let mcfg = MsgpConfig { n_per_dim: vec![64], n_var_samples: 4, ..Default::default() };
    let trainer = StreamTrainer::new(
        se_kernel(),
        0.01,
        grid,
        StreamConfig { msgp: mcfg, ..Default::default() },
    );
    let server = Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default());
    server.ingest(data.x.clone(), data.y.clone()).expect("ingest");
    server.flush_stream().expect("flush");
    let _ = server.predict(vec![0.5]).expect("predict");
    // The flush span guard drops just *after* the reply is sent, so
    // give the batcher thread a beat to publish it before dumping.
    let mut dump = Tracer::dump_json();
    for _ in 0..400 {
        if dump.contains("predict.flush") {
            break;
        }
        thread::sleep(Duration::from_millis(5));
        dump = Tracer::dump_json();
    }
    server.shutdown();
    Tracer::set_enabled(false);

    let doc = Json::parse(&dump).expect("trace dump parses");
    let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
    let field = |e: &Json, k: &str| e.get(k).and_then(|v| v.as_f64()).unwrap();
    let named = |name: &str| -> Vec<(f64, f64, f64)> {
        events
            .iter()
            .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some(name))
            .map(|e| (field(e, "tid"), field(e, "ts"), field(e, "dur")))
            .collect()
    };
    let refreshes = named("refresh");
    assert!(!refreshes.is_empty(), "no refresh span in trace");
    let (tid, ts, dur) = refreshes[0];
    let children =
        ["refresh.stage_rhs", "refresh.block_solve", "refresh.map_back", "refresh.slot_swap"];
    for child in children {
        let inside = named(child).iter().any(|&(ctid, cts, cdur)| {
            ctid == tid && cts >= ts - 1e-3 && cts + cdur <= ts + dur + 1e-3
        });
        assert!(inside, "{child} not nested inside the refresh span");
    }
    // The batched predict path is covered too.
    assert!(!named("predict.flush").is_empty(), "no predict.flush span");
    // Every event is a complete-phase slice with sane geometry.
    for e in events {
        assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert!(field(e, "dur") >= 0.0);
    }
}

/// Satellite (f) prerequisite: the recorder writes a well-formed
/// `BENCH_*.json` and skips configs that are already recorded.
#[test]
fn recorder_persists_well_formed_artifact() {
    let dir = std::env::temp_dir().join(format!("msgp_obs_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rec = Recorder::open_in(&dir, "it");
    assert!(rec.record_if_new("m=64", || {
        Record::from_duration("m=64", Duration::from_micros(120)).with_extra("iters", 3.0)
    }));
    rec.save().unwrap();

    let text = std::fs::read_to_string(dir.join("BENCH_it.json")).unwrap();
    let doc = Json::parse(&text).expect("artifact parses");
    assert_eq!(doc.get("figure").and_then(|f| f.as_str()), Some("it"));
    let entry = doc.get("entries").and_then(|e| e.get("m=64")).expect("entry");
    assert_eq!(entry.get("median_ns").and_then(|v| v.as_f64()), Some(120_000.0));
    assert_eq!(
        entry.get("config_hash").and_then(|v| v.as_str()),
        Some(config_hash("m=64").as_str())
    );

    let mut rec2 = Recorder::open_in(&dir, "it");
    assert!(!rec2.record_if_new("m=64", || panic!("must skip recorded config")));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite (f): the in-process route dispatch the CI smoke job uses —
/// `/metrics?format=prom`, `/healthz`, and `/trace` all answer through
/// the router against a live server.
#[test]
#[cfg_attr(miri, ignore = "fits a full MSGP model; far beyond Miri's budget")]
fn in_process_routes_serve_prometheus_health_and_trace() {
    let server = Server::start(serving_model(), EngineSpec::Native, BatcherConfig::default());
    let _ = server.predict(vec![0.0]).expect("predict");

    let prom = server.handle_path("/metrics?format=prom").expect("prom route");
    for family in ["submitted", "completed", "batches", "request_latency_us", "refresh_count"] {
        assert!(prom.contains(&format!("# TYPE {family} ")), "missing {family}");
    }
    assert_eq!(sample_of(&prom, "submitted"), Some(1));
    let legacy = server.handle_path("/metrics").expect("summary route");
    assert!(legacy.starts_with("submitted=1 "), "{legacy}");

    let health = server.handle_path("/healthz").expect("health route");
    let doc = Json::parse(&health).expect("healthz parses");
    assert_eq!(doc.get("status").and_then(|s| s.as_str()), Some("ok"));
    assert_eq!(doc.get("last_refresh_age_us"), Some(&Json::Null));

    let trace = server.handle_path("/trace").expect("trace route");
    assert!(Json::parse(&trace).unwrap().get("traceEvents").is_some());
    assert_eq!(server.handle_path("/nope"), None);
    server.shutdown();
}
