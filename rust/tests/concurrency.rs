//! Contention regression tests for the crate's concurrency
//! primitives: the hot-swap model slots readers race against trainer
//! publishes, the per-thread seqlock trace rings race drains against
//! writers, and the scoped thread pool is entered from many threads at
//! once. These are the suites the nightly ThreadSanitizer CI job runs
//! (see `docs/ANALYSIS.md`); under TSan any ordering regression in the
//! swap or seqlock paths shows up as a data-race report, and natively
//! the version-encoding assertions below catch torn or mixed-version
//! snapshots.
//!
//! Excluded under Miri: these tests are contention loops tuned for
//! real parallel hardware, and the lib tests already cover the same
//! primitives at Miri-friendly sizes.
#![cfg(not(miri))]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use msgp::coordinator::state::{ModelSlot, ServingModel, ShardSlots};
use msgp::grid::Grid;
use msgp::obs::trace as tracer;

/// A tiny 1-D serving model whose every field encodes `version`, so a
/// reader can detect a torn (mixed-version) snapshot: `u_mean` and
/// `nu_u` are constant-`version` vectors, and `kss` / `sigma2` carry
/// the same value shifted.
fn versioned_model(version: u64) -> ServingModel {
    let grid = Grid::covering(&[0.0, 1.0], 1, &[8], 2);
    let m = grid.m();
    let v = version as f64;
    ServingModel::from_parts(grid, vec![v; m], vec![v; m], v + 1.0, v + 0.5)
}

/// Assert one snapshot is internally consistent and return its version.
fn decode_version(model: &ServingModel) -> u64 {
    let v = model.u_mean[0];
    assert!(
        model.u_mean.iter().all(|&x| x == v),
        "torn u_mean: mixed versions in one snapshot"
    );
    assert!(
        model.nu_u.iter().all(|&x| x == v),
        "torn snapshot: nu_u version {} != u_mean version {v}",
        model.nu_u[0]
    );
    assert_eq!(model.kss, v + 1.0, "torn snapshot: kss from another version");
    assert_eq!(model.sigma2, v + 0.5, "torn snapshot: sigma2 from another version");
    v as u64
}

/// One writer hot-swaps versioned models into a [`ModelSlot`] while
/// reader threads continuously snapshot it. Every snapshot must be
/// internally consistent (a single version across all fields) and each
/// reader must observe versions in non-decreasing order — the
/// serializable behavior the `RwLock<Arc<_>>` swap path promises.
#[test]
fn model_slot_swap_under_contention() {
    const SWAPS: u64 = 2_000;
    const READERS: usize = 4;
    let slot = Arc::new(ModelSlot::new(versioned_model(0)));
    let done = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for _ in 0..READERS {
        let slot = Arc::clone(&slot);
        let done = Arc::clone(&done);
        readers.push(thread::spawn(move || {
            let mut last = 0u64;
            let mut seen = 0u64;
            while !done.load(Ordering::Acquire) {
                let snap = slot.get();
                let v = decode_version(&snap);
                assert!(v >= last, "version went backwards: {v} < {last}");
                last = v;
                seen += 1;
            }
            seen
        }));
    }
    for v in 1..=SWAPS {
        let old = slot.swap(versioned_model(v));
        decode_version(&old);
    }
    done.store(true, Ordering::Release);
    for r in readers {
        let seen = r.join().expect("reader panicked");
        assert!(seen > 0, "reader never snapshotted the slot");
    }
    assert_eq!(decode_version(&slot.get()), SWAPS);
}

/// Per-shard writers publish independently into a [`ShardSlots`] table
/// while readers sweep all shards. Versions are encoded per shard
/// (shard `s` publishes `s * STRIDE + k`), so a snapshot routed to the
/// wrong slot or torn across a swap fails the decode.
#[test]
fn shard_slots_swap_under_contention() {
    const SHARDS: usize = 4;
    const SWAPS: u64 = 500;
    const STRIDE: u64 = 1 << 20;
    let initial: Vec<ServingModel> =
        (0..SHARDS).map(|s| versioned_model(s as u64 * STRIDE)).collect();
    let slots = Arc::new(ShardSlots::new(initial));
    assert_eq!(slots.len(), SHARDS);
    let done = Arc::new(AtomicBool::new(false));
    let mut threads = Vec::new();
    for s in 0..SHARDS {
        let slots = Arc::clone(&slots);
        threads.push(thread::spawn(move || {
            for k in 1..=SWAPS {
                slots.swap(s, versioned_model(s as u64 * STRIDE + k));
            }
        }));
    }
    for _ in 0..2 {
        let slots = Arc::clone(&slots);
        let done = Arc::clone(&done);
        threads.push(thread::spawn(move || {
            let mut last = [0u64; SHARDS];
            while !done.load(Ordering::Acquire) {
                for s in 0..SHARDS {
                    let v = decode_version(&slots.get(s));
                    assert_eq!(
                        (v / STRIDE) as usize,
                        s,
                        "snapshot from shard {} surfaced in slot {s}",
                        v / STRIDE
                    );
                    assert!(v >= last[s], "shard {s} version went backwards");
                    last[s] = v;
                }
            }
        }));
    }
    // Writers are the first SHARDS handles; stop readers once they join.
    for (i, t) in threads.into_iter().enumerate() {
        t.join().expect("thread panicked");
        if i == SHARDS - 1 {
            done.store(true, Ordering::Release);
        }
    }
    for s in 0..SHARDS {
        assert_eq!(decode_version(&slots.get(s)), s as u64 * STRIDE + SWAPS);
    }
}

/// Hammer the per-thread seqlock trace rings: writer threads record
/// spans flat out while the main thread repeatedly drains. The seqlock
/// protocol must never surface a torn event — every drained event
/// carries a registered name, a plausible depth, and a duration that
/// does not precede its start.
#[test]
fn seqlock_drain_under_writers() {
    const WRITERS: usize = 4;
    const SPANS_PER_WRITER: usize = 20_000;
    tracer::set_enabled(true);
    let mut writers = Vec::new();
    for _ in 0..WRITERS {
        writers.push(thread::spawn(move || {
            for i in 0..SPANS_PER_WRITER {
                let _outer = msgp::span!("conc.outer");
                if i % 3 == 0 {
                    let _inner = msgp::span!("conc.inner");
                }
            }
        }));
    }
    let mut drains = 0usize;
    let mut total = 0usize;
    while writers.iter().any(|w| !w.is_finished()) || drains == 0 {
        let events = tracer::drain();
        for e in &events {
            assert!(
                e.name == "conc.outer" || e.name == "conc.inner",
                "drained an event with an unregistered/foreign name: {:?}",
                e.name
            );
            assert!(e.depth >= 1 && e.depth <= 2, "implausible depth {}", e.depth);
            assert!((e.tid as usize) < WRITERS + 2, "implausible tid {}", e.tid);
        }
        total += events.len();
        drains += 1;
    }
    for w in writers {
        w.join().expect("writer panicked");
    }
    // Final drain after all writers quiesce: the newest RING_CAP events
    // per ring are intact and readable.
    let events = tracer::drain();
    assert!(!events.is_empty(), "quiescent drain saw no events");
    for w in events.windows(2) {
        assert!(w[0].start_ns <= w[1].start_ns, "drain output not sorted");
    }
    total += events.len();
    assert!(total > 0, "no events across {drains} contended drains");
    tracer::set_enabled(false);
    tracer::clear();
}

/// Enter the shared thread pool from many threads at once: each entrant
/// sums a distinct slice range through `for_each_range`. Exactly one
/// entrant holds the pool per region (`try_acquire` / `BusyGuard`);
/// the rest run inline — either way the arithmetic must be exact.
#[test]
fn pool_regions_from_many_threads() {
    const ENTRANTS: usize = 8;
    const N: usize = 100_000;
    let mut threads = Vec::new();
    for e in 0..ENTRANTS {
        threads.push(thread::spawn(move || {
            let data: Vec<u64> = (0..N as u64).map(|i| i + e as u64).collect();
            let partials: Vec<std::sync::Mutex<u64>> =
                (0..16).map(|_| std::sync::Mutex::new(0)).collect();
            let fanned = msgp::parallel::for_each_range(N, 16, &|r| {
                let s: u64 = data[r.clone()].iter().sum();
                let mut cell = partials[r.start * 16 / N].lock().unwrap();
                *cell += s;
            });
            // 0 = ran inline (pool busy with a sibling entrant), else
            // the full fan-out; both are correct under contention.
            assert!(fanned == 0 || fanned == 16, "unexpected fan-out {fanned}");
            let got: u64 = partials.iter().map(|c| *c.lock().unwrap()).sum();
            let want: u64 = data.iter().sum();
            assert_eq!(got, want, "entrant {e} lost or duplicated a chunk");
        }));
    }
    for t in threads {
        t.join().expect("pool entrant panicked");
    }
}
