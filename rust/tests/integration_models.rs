//! Cross-model integration: all five GP implementations on the same
//! workload, checking the relationships the paper's evaluation relies on
//! (MSGP ~ exact at large m; baselines sane; BTTB path consistent with
//! Kronecker path on separable problems).

use msgp::data::{gen_stress_1d, gen_stress_2d, smae};
use msgp::gp::exact::ExactGp;
use msgp::gp::fitc::Fitc;
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::gp::ssgp::Ssgp;
use msgp::kernels::{KernelType, ProductKernel};

#[test]
fn all_methods_beat_the_mean_predictor_on_stress_data() {
    let train = gen_stress_1d(400, 0.05, 1);
    let test = gen_stress_1d(200, 0.0, 2);
    let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
    let mut scores = Vec::new();
    let exact = ExactGp::fit(kernel.clone(), 0.01, train.clone()).unwrap();
    scores.push(("exact", smae(&exact.predict_mean(&test.x), &test.y)));
    let fitc = Fitc::fit_grid_1d(kernel.clone(), 0.01, train.clone(), 64, -11.0, 11.0).unwrap();
    scores.push(("fitc", smae(&fitc.predict_mean(&test.x), &test.y)));
    let ssgp = Ssgp::fit(kernel.clone(), 0.01, train.clone(), 128, 3).unwrap();
    scores.push(("ssgp", smae(&ssgp.predict_mean(&test.x), &test.y)));
    let msgp = MsgpModel::fit(
        KernelSpec::Product(kernel),
        0.01,
        train,
        MsgpConfig { n_per_dim: vec![256], ..Default::default() },
    )
    .unwrap();
    scores.push(("msgp", smae(&msgp.predict_mean(&test.x), &test.y)));
    for (name, s) in &scores {
        assert!(*s < 0.5, "{name} SMAE {s}");
    }
    // MSGP with large m should be within 20% relative SMAE of exact.
    let exact_s = scores[0].1;
    let msgp_s = scores[3].1;
    assert!(msgp_s < exact_s * 1.3 + 0.02, "msgp {msgp_s} vs exact {exact_s}");
}

#[test]
fn msgp_accuracy_improves_with_m() {
    // The Figure-4 monotonicity claim: more inducing points, better mean.
    let train = gen_stress_1d(800, 0.05, 4);
    let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
    let exact = ExactGp::fit(kernel.clone(), 0.01, train.clone()).unwrap();
    let test: Vec<f64> = (0..300).map(|i| -9.5 + 19.0 * i as f64 / 299.0).collect();
    let gold = exact.predict_mean(&test);
    let mut errs = Vec::new();
    for m in [32usize, 64, 256] {
        let model = MsgpModel::fit(
            KernelSpec::Product(kernel.clone()),
            0.01,
            train.clone(),
            MsgpConfig { n_per_dim: vec![m], ..Default::default() },
        )
        .unwrap();
        errs.push(smae(&model.predict_mean(&test), &gold));
    }
    assert!(errs[2] < errs[0], "no improvement: {errs:?}");
    assert!(errs[2] < 0.02, "large-m error vs exact too big: {errs:?}");
}

#[test]
fn bttb_and_kronecker_paths_agree_on_separable_2d_kernel() {
    // An isotropic SE kernel *is* separable (exp(-r^2) factorizes), so the
    // BTTB path and the Kronecker path model the same prior and must give
    // near-identical predictions.
    let train = gen_stress_2d(250, 0.05, 5);
    let ell = 1.2f64;
    let kron = MsgpModel::fit(
        KernelSpec::Product(ProductKernel::iso(KernelType::SE, 2, ell, 1.0)),
        0.01,
        train.clone(),
        MsgpConfig { n_per_dim: vec![40, 40], ..Default::default() },
    )
    .unwrap();
    let bttb = MsgpModel::fit(
        KernelSpec::Iso {
            ktype: KernelType::SE,
            log_ell: ell.ln(),
            log_sf2: 0.0,
            dim: 2,
        },
        0.01,
        train.clone(),
        MsgpConfig { n_per_dim: vec![40, 40], ..Default::default() },
    )
    .unwrap();
    let test = gen_stress_2d(100, 0.0, 6);
    let pk = kron.predict_mean(&test.x);
    let pb = bttb.predict_mean(&test.x);
    for (a, b) in pk.iter().zip(&pb) {
        assert!((a - b).abs() < 5e-3, "{a} vs {b}");
    }
    // Their marginal likelihoods agree too (same prior, same data).
    assert!(
        (kron.lml() - bttb.lml()).abs() < 0.05 * kron.lml().abs(),
        "{} vs {}",
        kron.lml(),
        bttb.lml()
    );
}

#[test]
fn training_recovers_reasonable_hypers_from_misspecified_start() {
    let train = gen_stress_1d(600, 0.1, 8);
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 5.0, 3.0));
    let mut model = MsgpModel::fit(
        kernel,
        1.0, // badly over-estimated noise
        train,
        MsgpConfig { n_per_dim: vec![256], ..Default::default() },
    )
    .unwrap();
    model.train(40, 0.1).unwrap();
    // Noise should come down towards the true 0.01 (= 0.1^2).
    assert!(model.sigma2 < 0.2, "sigma2 {}", model.sigma2);
    let test = gen_stress_1d(200, 0.0, 9);
    let err = smae(&model.predict_mean(&test.x), &test.y);
    assert!(err < 0.25, "SMAE {err}");
}
