//! The HTTP front door, end to end over real sockets: bit-for-bit
//! parity between HTTP and in-process predictions, per-route metric
//! exactness, request-scoped trace spans, keep-alive + pipelining
//! framing, malformed-input hardening, query-string routes, and a
//! closed-loop `loadgen` run — everything the transport promises,
//! asserted against a live sharded server on an ephemeral loopback
//! port.
//!
//! Excluded under Miri: the whole suite runs over real TCP sockets,
//! which Miri does not model even with isolation disabled.
#![cfg(not(miri))]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use msgp::bench::loadgen::{run, HttpClient, LoadConfig};
use msgp::coordinator::{BatcherConfig, HttpConfig, HttpErrClass, HttpServer, Server};
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::obs::Tracer;
use msgp::shard::{ShardConfig, ShardedTrainer};
use msgp::util::json::Json;
use msgp::util::Rng;

/// Boot a warmed 2+-shard server behind the front door on an ephemeral
/// loopback port. `refresh_every` is pinned to `usize::MAX` so model
/// swaps happen only on explicit flushes (deterministic parity).
fn boot(shards: usize, http_cfg: HttpConfig) -> HttpServer {
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]);
    let cfg = ShardConfig {
        shards,
        refresh_every: usize::MAX,
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() },
        ..Default::default()
    };
    let trainer = ShardedTrainer::start(kernel, 0.01, grid, cfg);
    let warm = gen_stress_1d(1500, 0.05, 3);
    trainer.ingest_batch(&warm.x, &warm.y);
    trainer.flush();
    let server = Arc::new(Server::start_sharded(trainer, BatcherConfig::default()));
    HttpServer::bind(server, "127.0.0.1:0", http_cfg).expect("bind loopback front door")
}

fn predict_body(xs: &[f64]) -> String {
    let pts = xs.iter().map(|&x| Json::Num(x)).collect();
    Json::obj(vec![("points", Json::Arr(pts))]).to_string()
}

fn ingest_body(xs: &[f64], ys: &[f64], flush: bool) -> String {
    Json::obj(vec![
        ("xs", Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())),
        ("ys", Json::Arr(ys.iter().map(|&y| Json::Num(y)).collect())),
        ("flush", Json::Bool(flush)),
    ])
    .to_string()
}

fn parse_mean_var(body: &str) -> (Vec<f64>, Vec<f64>) {
    let doc = Json::parse(body).expect("predict reply parses");
    let arr = |k: &str| -> Vec<f64> {
        doc.get(k)
            .and_then(|v| v.as_arr())
            .expect("numeric array")
            .iter()
            .map(|v| v.as_f64().expect("number"))
            .collect()
    };
    (arr("mean"), arr("var"))
}

fn sample_of(prom: &str, name: &str) -> Option<u64> {
    prom.lines().find_map(|l| {
        let rest = l.strip_prefix(name)?;
        rest.strip_prefix(' ')?.trim().parse::<u64>().ok()
    })
}

/// Tentpole acceptance: concurrent HTTP predict/ingest traffic, then
/// sequential predictions compared bit-for-bit with the in-process
/// path, then a `/metrics?format=prom` scrape whose per-route
/// `http_request_latency_us` counts equal the exact number of requests
/// sent over the wire.
#[test]
fn http_predictions_match_in_process_bit_for_bit_and_metrics_count_requests() {
    let http = boot(2, HttpConfig::default());
    let addr = http.local_addr();
    let server = http.server().clone();

    // Concurrent phase: 4 clients x (10 predicts + 2 ingests).
    thread::scope(|s| {
        for t in 0..4u64 {
            s.spawn(move || {
                let mut client = HttpClient::new(addr);
                let mut rng = Rng::new(100 + t);
                for k in 0..12 {
                    let read = k < 10;
                    let body = if read {
                        let p = [rng.uniform_in(-9.0, 9.0), rng.uniform_in(-9.0, 9.0)];
                        predict_body(&p)
                    } else {
                        let xs = [rng.uniform_in(-9.0, 9.0), rng.uniform_in(-9.0, 9.0)];
                        let ys = [msgp::data::stress_fn(xs[0]), msgp::data::stress_fn(xs[1])];
                        ingest_body(&xs, &ys, false)
                    };
                    let path = if read { "/predict" } else { "/ingest" };
                    let (status, text) =
                        client.request("POST", path, Some(&body)).expect("request");
                    assert_eq!(status, 200, "{path}: {text}");
                }
            });
        }
    });

    // Publish the concurrent ingests, then compare sequentially.
    let mut client = HttpClient::new(addr);
    let flush = ingest_body(&[], &[], true);
    let (status, _) = client.request("POST", "/ingest", Some(&flush)).expect("flush ingest");
    assert_eq!(status, 200);
    let mut rng = Rng::new(9);
    for _ in 0..13 {
        let x = rng.uniform_in(-9.0, 9.0);
        let (status, text) =
            client.request("POST", "/predict", Some(&predict_body(&[x]))).expect("predict");
        assert_eq!(status, 200, "{text}");
        let (mean, var) = parse_mean_var(&text);
        let local = server.predict(vec![x]).expect("in-process predict");
        assert_eq!(mean, vec![local.mean], "HTTP mean differs at x={x}");
        assert_eq!(var, vec![local.var], "HTTP var differs at x={x}");
    }

    // 40 concurrent + 13 sequential predicts; 8 concurrent + 1 flush
    // ingests. The route counters record just after the response bytes
    // are written, so poll briefly for the last stragglers.
    let (predicts, ingests) = (53u64, 9u64);
    let mut prom = String::new();
    for _ in 0..200 {
        let (status, text) =
            client.request("GET", "/metrics?format=prom", None).expect("prom scrape");
        assert_eq!(status, 200);
        prom = text;
        if sample_of(&prom, "http_request_latency_us_count{route=\"predict\"}") == Some(predicts) {
            break;
        }
        thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(
        sample_of(&prom, "http_request_latency_us_count{route=\"predict\"}"),
        Some(predicts),
        "{prom}"
    );
    assert_eq!(
        sample_of(&prom, "http_request_latency_us_bucket{route=\"predict\",le=\"+Inf\"}"),
        Some(predicts)
    );
    assert_eq!(
        sample_of(&prom, "http_requests_total{route=\"predict\",class=\"2xx\"}"),
        Some(predicts)
    );
    assert_eq!(
        sample_of(&prom, "http_requests_total{route=\"ingest\",class=\"2xx\"}"),
        Some(ingests)
    );
    assert_eq!(sample_of(&prom, "http_errors_total{class=\"bad_request\"}"), Some(0));
    // The legacy summary carries the aggregate front-door keys.
    let (_, summary) = client.request("GET", "/metrics", None).expect("summary scrape");
    assert!(summary.contains("http_requests_total="), "{summary}");
    assert!(summary.contains("http_connections_total="), "{summary}");

    drop(client);
    http.shutdown();
}

/// Tentpole acceptance: a `/trace` dump fetched over the wire contains
/// an `http.request` span (carrying its request id) that time-encloses
/// the `refresh` done by a flushing ingest, plus a `predict.flush`
/// child for the batched predict path; `/trace?clear=1` then drains
/// those spans from the rings.
#[test]
fn trace_dump_shows_http_request_spans_enclosing_handler_children() {
    let http = boot(2, HttpConfig::default());
    let addr = http.local_addr();
    Tracer::set_enabled(true);
    let mut client = HttpClient::new(addr);

    let mut rng = Rng::new(31);
    let n = 200;
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let x = rng.uniform_in(-9.0, 9.0);
        xs.push(x);
        ys.push(msgp::data::stress_fn(x) + 0.05 * rng.normal());
    }
    let (status, _) =
        client.request("POST", "/ingest", Some(&ingest_body(&xs, &ys, true))).expect("ingest");
    assert_eq!(status, 200);
    let (status, _) =
        client.request("POST", "/predict", Some(&predict_body(&[0.5]))).expect("predict");
    assert_eq!(status, 200);

    // The predict.flush guard drops just after the reply is sent, so
    // poll the trace route until the batcher thread has published it.
    let mut dump = String::new();
    for _ in 0..400 {
        let (status, text) = client.request("GET", "/trace", None).expect("trace fetch");
        assert_eq!(status, 200);
        dump = text;
        if dump.contains("predict.flush") && dump.contains("http.request") {
            break;
        }
        thread::sleep(Duration::from_millis(5));
    }
    Tracer::set_enabled(false);

    let doc = Json::parse(&dump).expect("trace dump parses");
    let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
    let field = |e: &Json, k: &str| e.get(k).and_then(|v| v.as_f64()).unwrap();
    let named = |name: &str| -> Vec<&Json> {
        events
            .iter()
            .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some(name))
            .collect()
    };
    let requests = named("http.request");
    assert!(!requests.is_empty(), "no http.request span in trace");
    for e in &requests {
        let id = e.get("args").and_then(|a| a.get("id")).and_then(|v| v.as_f64());
        assert!(id.unwrap_or(0.0) > 0.0, "http.request span without a request id");
    }
    // The flushing ingest's refresh runs on a shard worker thread, so
    // assert time containment (any tid) under some http.request span.
    let refreshes = named("refresh");
    assert!(!refreshes.is_empty(), "no refresh span in trace");
    let enclosed = refreshes.iter().any(|r| {
        let (rts, rdur) = (field(r, "ts"), field(r, "dur"));
        requests.iter().any(|q| {
            let (qts, qdur) = (field(q, "ts"), field(q, "dur"));
            rts >= qts - 1e-3 && rts + rdur <= qts + qdur + 1e-3
        })
    });
    assert!(enclosed, "no refresh span inside an http.request span");
    assert!(!named("predict.flush").is_empty(), "no predict.flush span");

    // `/trace?clear=1` dumps then drains: the refresh span observed
    // above (matched by timestamp — other tests may refresh anew) must
    // be gone from the next dump.
    let seen_ts = field(refreshes[0], "ts");
    let (status, cleared) = client.request("GET", "/trace?clear=1", None).expect("trace clear");
    assert_eq!(status, 200);
    assert!(cleared.contains("traceEvents"));
    let (_, after) = client.request("GET", "/trace", None).expect("trace refetch");
    let doc = Json::parse(&after).expect("post-clear dump parses");
    let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
    let survived = events.iter().any(|e| {
        e.get("name").and_then(|n| n.as_str()) == Some("refresh")
            && (field(e, "ts") - seen_ts).abs() < 1e-6
    });
    assert!(!survived, "refresh span survived /trace?clear=1");

    drop(client);
    http.shutdown();
}

/// Read one `Content-Length`-framed response out of `stream`, carrying
/// leftover bytes (the next pipelined response) across calls in `buf`.
fn read_framed_response(stream: &mut TcpStream, buf: &mut Vec<u8>) -> (u16, String) {
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let mut tmp = [0u8; 4096];
        let n = stream.read(&mut tmp).expect("read response");
        assert!(n > 0, "eof before a full response head");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let status: u16 = head
        .split("\r\n")
        .next()
        .and_then(|l| l.split(' ').nth(1))
        .and_then(|s| s.parse().ok())
        .expect("status code");
    let len: usize = head
        .split("\r\n")
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().ok())?
        })
        .expect("content-length header");
    let total = head_end + 4 + len;
    while buf.len() < total {
        let mut tmp = [0u8; 4096];
        let n = stream.read(&mut tmp).expect("read response body");
        assert!(n > 0, "eof before a full response body");
        buf.extend_from_slice(&tmp[..n]);
    }
    let body = String::from_utf8_lossy(&buf[head_end + 4..total]).to_string();
    buf.drain(..total);
    (status, body)
}

/// Satellite: keep-alive means N sequential requests ride one accepted
/// connection, and pipelined requests written back-to-back come back
/// in order with correct framing.
#[test]
fn keep_alive_reuses_the_socket_and_pipelined_requests_answer_in_order() {
    let http = boot(2, HttpConfig::default());
    let addr = http.local_addr();
    let server = http.server().clone();
    let before = server.metrics.http.connections_total.get();

    let mut client = HttpClient::new(addr);
    for i in 0..5 {
        let x = -2.0 + i as f64;
        let (status, _) =
            client.request("POST", "/predict", Some(&predict_body(&[x]))).expect("predict");
        assert_eq!(status, 200);
    }
    assert_eq!(
        server.metrics.http.connections_total.get() - before,
        1,
        "5 keep-alive requests must reuse one connection"
    );

    // Pipelining: three requests written back-to-back before reading
    // anything; responses must come back in request order.
    let xs = [0.1, 0.2, 0.3];
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).unwrap();
    let mut wire = Vec::new();
    for x in xs {
        let body = predict_body(&[x]);
        wire.extend_from_slice(
            format!("POST /predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len())
                .as_bytes(),
        );
    }
    stream.write_all(&wire).expect("write pipelined requests");
    let mut buf = Vec::new();
    for x in xs {
        let (status, text) = read_framed_response(&mut stream, &mut buf);
        assert_eq!(status, 200, "{text}");
        let (mean, var) = parse_mean_var(&text);
        let local = server.predict(vec![x]).expect("in-process predict");
        assert_eq!((mean, var), (vec![local.mean], vec![local.var]), "order broken at x={x}");
    }
    assert_eq!(server.metrics.http.connections_total.get() - before, 2);

    drop(stream);
    drop(client);
    http.shutdown();
}

/// Satellite: malformed input answers 4xx/5xx and increments
/// `http_errors_total{class=...}` instead of killing the worker — the
/// server keeps serving afterwards.
#[test]
fn malformed_input_is_counted_and_never_worker_fatal() {
    let http = boot(2, HttpConfig { max_head_bytes: 1024, ..HttpConfig::default() });
    let addr = http.local_addr();
    let server = http.server().clone();
    let errs = |class: HttpErrClass| server.metrics.http.errors[class as usize].get();

    // Raw exchange against a fresh connection; the server closes it
    // after the error response, so read-to-EOF terminates.
    let raw = |payload: &[u8]| -> String {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(payload).expect("write");
        let mut text = String::new();
        let _ = s.read_to_string(&mut text);
        text
    };

    // Oversized request head -> 431.
    let t0 = errs(HttpErrClass::TooLarge);
    let resp = raw(&[b'A'; 2048]);
    assert!(resp.starts_with("HTTP/1.1 431 "), "{resp}");
    assert_eq!(errs(HttpErrClass::TooLarge), t0 + 1);

    // Unparseable content-length -> 400.
    let b0 = errs(HttpErrClass::BadRequest);
    let resp = raw(b"POST /predict HTTP/1.1\r\nContent-Length: abc\r\n\r\n");
    assert!(resp.starts_with("HTTP/1.1 400 "), "{resp}");
    assert_eq!(errs(HttpErrClass::BadRequest), b0 + 1);

    // Declared body over the cap -> 413 (without reading the body).
    let resp = raw(b"POST /predict HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n");
    assert!(resp.starts_with("HTTP/1.1 413 "), "{resp}");
    assert_eq!(errs(HttpErrClass::TooLarge), t0 + 2);

    // Unknown route -> 404; wrong method on a real route -> 405. Both
    // keep the connection alive, so use the framing client.
    let mut client = HttpClient::new(addr);
    let u0 = errs(HttpErrClass::UnknownRoute);
    let (status, _) = client.request("GET", "/nope", None).expect("unknown route");
    assert_eq!(status, 404);
    assert_eq!(errs(HttpErrClass::UnknownRoute), u0 + 1);
    let (status, _) = client.request("GET", "/predict", None).expect("GET predict");
    assert_eq!(status, 405);

    // Bad JSON body on a good route -> 400, connection still usable.
    let (status, text) = client.request("POST", "/predict", Some("not json")).expect("bad json");
    assert_eq!(status, 400, "{text}");
    let (status, text) =
        client.request("POST", "/predict", Some(&predict_body(&[]))).expect("empty points");
    assert_eq!(status, 400, "{text}");

    // Early client disconnect mid-request is counted, not fatal.
    let d0 = errs(HttpErrClass::Disconnect);
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(b"POST /pred").expect("partial write");
    }
    let mut waited = 0;
    while errs(HttpErrClass::Disconnect) == d0 && waited < 400 {
        thread::sleep(Duration::from_millis(5));
        waited += 1;
    }
    assert_eq!(errs(HttpErrClass::Disconnect), d0 + 1, "disconnect not counted");

    // The workers survived all of the above.
    let (status, text) =
        client.request("POST", "/predict", Some(&predict_body(&[0.5]))).expect("still serving");
    assert_eq!(status, 200, "{text}");

    drop(client);
    http.shutdown();
}

/// Satellite: query-string routes over the wire — `/shards?verbose=1`
/// extends the layout with live per-shard counters, `/healthz` parses,
/// and the Prometheus rendering arrives with the serving families.
#[test]
fn query_string_routes_answer_over_the_wire() {
    let http = boot(2, HttpConfig::default());
    let addr = http.local_addr();
    let mut client = HttpClient::new(addr);

    let (status, shards) = client.request("GET", "/shards", None).expect("shards");
    assert_eq!(status, 200);
    assert!(shards.contains("shards=2"), "{shards}");
    assert!(!shards.contains("cg_iters="), "terse layout must stay terse: {shards}");
    let (status, verbose) = client.request("GET", "/shards?verbose=1", None).expect("verbose");
    assert_eq!(status, 200);
    assert!(verbose.contains("cg_iters="), "{verbose}");
    assert!(verbose.contains("refreshes="), "{verbose}");

    let (status, health) = client.request("GET", "/healthz", None).expect("healthz");
    assert_eq!(status, 200);
    let doc = Json::parse(&health).expect("healthz parses");
    assert_eq!(doc.get("status").and_then(|s| s.as_str()), Some("ok"));
    assert_eq!(doc.get("shards").and_then(|s| s.as_f64()), Some(2.0));

    let (status, prom) = client.request("GET", "/metrics?format=prom", None).expect("prom");
    assert_eq!(status, 200);
    assert!(prom.contains("# TYPE submitted counter"), "{prom}");
    assert!(prom.contains("# TYPE http_requests_total counter"), "{prom}");

    drop(client);
    http.shutdown();
}

/// Satellite: the loadgen harness drives a live front door closed-loop
/// and reports exact counts and monotone quantiles.
#[test]
fn loadgen_closed_loop_reports_counts_and_monotone_quantiles() {
    let http = boot(2, HttpConfig::default());
    let report = run(&LoadConfig {
        addr: http.local_addr(),
        clients: 2,
        requests_per_client: 20,
        ..LoadConfig::default()
    });
    assert_eq!(report.requests, 40);
    assert_eq!(report.errors, 0, "loadgen saw errors: {}", report.summary_line());
    assert_eq!(report.predict_requests + report.ingest_requests, 40);
    assert!(report.predict_requests > 0, "read_frac=0.9 sent no predicts");
    assert!(report.qps > 0.0);
    let (p50, p99, p999) =
        (report.quantile_us(0.5), report.quantile_us(0.99), report.quantile_us(0.999));
    assert!(p50 <= p99 && p99 <= p999, "non-monotone quantiles {p50}/{p99}/{p999}");
    http.shutdown();
}
