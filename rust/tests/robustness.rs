//! Chaos and crash-recovery suite: failpoint-injected panics under the
//! supervised workers, degradation tiers under refresh deadlines, and
//! checkpoint/restore parity of the SKI sufficient statistics.
//!
//! The failpoint registry and the `MSGP_*` environment knobs are
//! process-global, so every test that touches either serializes on one
//! static mutex — the suite stays correct under the default parallel
//! test runner.

#![cfg(not(miri))] // thread/FS-heavy; far beyond Miri's budget

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{Mutex, MutexGuard};

use msgp::coordinator::{BatcherConfig, EngineSpec, Server};
use msgp::data::gen_stress_1d;
use msgp::fault;
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::shard::{ShardConfig, ShardedTrainer};
use msgp::stream::{StreamConfig, StreamTrainer};
use msgp::util::json::Json;

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn se_kernel() -> KernelSpec {
    KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0))
}

fn stream_cfg(m: usize, refresh_every: usize) -> StreamConfig {
    StreamConfig {
        msgp: MsgpConfig { n_per_dim: vec![m], n_var_samples: 4, ..Default::default() },
        refresh_every,
        ..Default::default()
    }
}

fn online_server(refresh_every: usize) -> Server {
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]);
    let trainer = StreamTrainer::new(se_kernel(), 0.01, grid, stream_cfg(128, refresh_every));
    Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default())
}

/// A per-test scratch directory under the system temp dir, removed on
/// drop so a failed assertion never leaks checkpoints into later runs.
struct ScratchDir(PathBuf);

impl ScratchDir {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("msgp-robustness-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("create scratch dir");
        ScratchDir(dir)
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Clears checkpoint/deadline env knobs on construction *and* drop, so
/// a panicking test cannot leave them set for the next one.
struct EnvReset;

impl EnvReset {
    fn new() -> Self {
        Self::clear();
        EnvReset
    }
    fn clear() {
        std::env::remove_var("MSGP_CKPT_DIR");
        std::env::remove_var("MSGP_CKPT_EVERY_POINTS");
        std::env::remove_var("MSGP_CKPT_EVERY_MS");
        std::env::remove_var("MSGP_REFRESH_DEADLINE_MS");
        std::env::remove_var("MSGP_FAILPOINTS");
        fault::clear_all();
    }
}

impl Drop for EnvReset {
    fn drop(&mut self) {
        Self::clear();
    }
}

/// Injected refresh panics are supervised: the batch is dropped, the
/// ingest worker restarts with backoff, serving never stops, and once
/// the failpoint clears the stream trains through to a good model.
#[test]
fn refresh_panics_are_supervised_and_serving_recovers() {
    let _g = guard();
    let _env = EnvReset::new();
    let server = online_server(100);
    let data = gen_stress_1d(800, 0.05, 7);
    // Every cadence refresh panics inside the block solve.
    fault::configure("refresh.block_solve=panic").unwrap();
    // Three 100-point batches -> three refresh attempts -> three panics
    // (staying under the poison budget of 5-in-30s). Ingest acks before
    // the refresh, so the ingest calls themselves still succeed.
    for c in 0..3 {
        let lo = c * 100;
        let _ = server.ingest(data.x[lo..lo + 100].to_vec(), data.y[lo..lo + 100].to_vec());
        // Predictions keep flowing off the last-good (prior) snapshot
        // while the refresh path is on fire.
        let p = server.predict(vec![0.0]).expect("serving must survive refresh panics");
        assert!(p.mean.is_finite() && p.var.is_finite());
    }
    // Give the supervised worker time to finish its backoff sleeps.
    std::thread::sleep(std::time::Duration::from_millis(200));
    let restarts = server.metrics.worker_restarts[0].get();
    assert!(restarts >= 1, "ingest worker restarts not recorded: {restarts}");
    let (healthy, body) = server.health();
    assert!(healthy, "restarts alone must not fail health: {body}");
    // Heal the failpoint; the retained statistics (ingests were acked
    // before each panic) now train through.
    fault::clear_all();
    for c in 3..8 {
        let lo = c * 100;
        let k = server
            .ingest(data.x[lo..lo + 100].to_vec(), data.y[lo..lo + 100].to_vec())
            .expect("post-chaos ingest");
        assert_eq!(k, 100);
    }
    server.flush_stream().expect("post-chaos flush");
    let p = server.predict(vec![1.5]).unwrap();
    let want = msgp::data::stress_fn(1.5);
    assert!((p.mean - want).abs() < 0.15, "{} vs {want}", p.mean);
    server.shutdown();
}

/// Exhausting the restart budget poisons the worker: ingest fails
/// cleanly (no hang), `/healthz` flips unhealthy with a reason, and
/// prediction keeps serving the last-good snapshot.
#[test]
fn repeated_panics_poison_the_worker_and_flip_health() {
    let _g = guard();
    let _env = EnvReset::new();
    let server = online_server(1_000_000);
    fault::configure("ingest.batch=panic").unwrap();
    // The failpoint fires before the early ack, so every caller gets a
    // clean channel error; the 5th failure inside the window poisons.
    let mut errors = 0;
    for _ in 0..6 {
        if server.ingest(vec![0.5], vec![1.0]).is_err() {
            errors += 1;
        }
    }
    assert!(errors >= 5, "panicking batches must error back to callers: {errors}/6");
    // The caller's error races the supervisor's bookkeeping by a few
    // instructions; let the worker settle before reading the counters.
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert!(server.metrics.worker_restarts[0].get() >= 5);
    assert_eq!(server.metrics.worker_poisoned.get(), 1);
    let (healthy, body) = server.health();
    assert!(!healthy, "{body}");
    assert!(body.contains("poisoned"), "{body}");
    // The batcher and its prediction path are a separate worker: still up.
    let p = server.predict(vec![0.0]).expect("prediction survives a poisoned ingest worker");
    assert!(p.mean.is_finite());
    fault::clear_all();
    server.shutdown();
}

/// A refresh that overruns its soft deadline must not publish the
/// half-converged cache: the slot keeps the last-good snapshot and the
/// `degraded_mode` gauge (and `/healthz` `degraded` field) flips on.
#[test]
fn deadline_overrun_enters_degraded_mode_and_keeps_last_good_snapshot() {
    let _g = guard();
    let _env = EnvReset::new();
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 64)]);
    let mut cfg = stream_cfg(64, 100);
    cfg.refresh_deadline_ms = Some(0); // every refresh overruns
    let trainer = StreamTrainer::new(se_kernel(), 0.01, grid, cfg);
    let server = Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default());
    let prior = server.predict(vec![0.0]).unwrap();
    let data = gen_stress_1d(200, 0.05, 13);
    server.ingest(data.x.clone(), data.y.clone()).unwrap();
    server.flush_stream().unwrap();
    assert_eq!(server.metrics.degraded_mode.get(), 1, "deadline overrun must flip the gauge");
    // Degraded, not unhealthy: the last-good snapshot still serves.
    let (healthy, body) = server.health();
    assert!(healthy, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("degraded"), Some(&Json::Bool(true)), "{body}");
    let p = server.predict(vec![0.0]).unwrap();
    assert!(
        (p.mean - prior.mean).abs() < 1e-12,
        "degraded server must keep serving the pre-overrun snapshot"
    );
    server.shutdown();
}

/// Crash-safe restore, unsharded: a server killed after absorbing part
/// of the stream restarts from its checkpoint and — after the rest of
/// the stream — serves predictions identical (1e-10) to one trainer
/// that saw the whole stream uninterrupted.
#[test]
fn checkpoint_restart_matches_uninterrupted_run_to_1e10() {
    let _g = guard();
    let _env = EnvReset::new();
    let scratch = ScratchDir::new("unsharded");
    std::env::set_var("MSGP_CKPT_DIR", &scratch.0);
    std::env::set_var("MSGP_CKPT_EVERY_POINTS", "100");
    let data = gen_stress_1d(1200, 0.05, 23);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]);
    let probe: Vec<f64> = (0..100).map(|i| -9.0 + 0.18 * i as f64).collect();
    // Uninterrupted reference: same batch boundaries, same refresh
    // schedule (cold refresh after 600, warm refresh at the end).
    let mut reference = StreamTrainer::new(se_kernel(), 0.01, grid.clone(), stream_cfg(128, 1_000_000));
    reference.ingest_batch(&data.x[..600], &data.y[..600]);
    reference.refresh();
    reference.ingest_batch(&data.x[600..], &data.y[600..]);
    reference.refresh();
    let (ref_mean, ref_var) = reference.serving_model().predict_batch(&probe);
    // Run A: absorb the first half, then die (graceful here; the codec
    // tests + crash_recovery example cover the SIGKILL torn-write case).
    let trainer_a = StreamTrainer::new(se_kernel(), 0.01, grid.clone(), stream_cfg(128, 1_000_000));
    let server_a = Server::start_online(trainer_a, EngineSpec::Native, BatcherConfig::default());
    for c in 0..6 {
        let lo = c * 100;
        let k = server_a.ingest(data.x[lo..lo + 100].to_vec(), data.y[lo..lo + 100].to_vec()).unwrap();
        assert_eq!(k, 100);
    }
    server_a.shutdown(); // graceful shutdown persists the final statistics
    assert!(scratch.0.join("ski.ckpt").exists(), "shutdown checkpoint missing");
    // Run B: a fresh (empty) trainer restores the 600 absorbed points
    // from the checkpoint, replays the refresh, then finishes the stream.
    let trainer_b = StreamTrainer::new(se_kernel(), 0.01, grid, stream_cfg(128, 1_000_000));
    let server_b = Server::start_online(trainer_b, EngineSpec::Native, BatcherConfig::default());
    assert_eq!(server_b.metrics.ckpt_restores_total.get(), 1, "restore not recorded");
    assert!(server_b.metrics.ckpt_last_seq.get() >= 1);
    for c in 6..12 {
        let lo = c * 100;
        let k = server_b.ingest(data.x[lo..lo + 100].to_vec(), data.y[lo..lo + 100].to_vec()).unwrap();
        assert_eq!(k, 100);
    }
    server_b.flush_stream().unwrap();
    for (i, &x) in probe.iter().enumerate() {
        let p = server_b.predict(vec![x]).unwrap();
        assert!(
            (p.mean - ref_mean[i]).abs() < 1e-10,
            "mean parity at x={x}: {} vs {}",
            p.mean,
            ref_mean[i]
        );
        assert!(
            (p.var - ref_var[i]).abs() < 1e-10,
            "var parity at x={x}: {} vs {}",
            p.var,
            ref_var[i]
        );
    }
    server_b.shutdown();
}

/// A corrupt checkpoint (both the file and its rotation) is detected by
/// the checksum and ignored: the server starts clean instead of
/// crashing or restoring garbage.
#[test]
fn corrupt_checkpoints_are_ignored_and_the_server_starts_clean() {
    let _g = guard();
    let _env = EnvReset::new();
    let scratch = ScratchDir::new("corrupt");
    std::env::set_var("MSGP_CKPT_DIR", &scratch.0);
    std::fs::write(scratch.0.join("ski.ckpt"), b"MSGPCKPT garbage that fails the checksum").unwrap();
    std::fs::write(scratch.0.join("ski.ckpt.1"), b"not even magic").unwrap();
    let server = online_server(1_000_000);
    assert_eq!(server.metrics.ckpt_restores_total.get(), 0);
    let p = server.predict(vec![0.0]).unwrap();
    assert!(p.mean.abs() < 1e-9, "must serve the clean prior, got {}", p.mean);
    server.shutdown();
}

/// A corrupt *primary* checkpoint with a valid rotation restores from
/// `ski.ckpt.1` instead of cold-starting: the checksum rejects the torn
/// primary, `load_newest` falls back, the restore is recorded, and the
/// rotated statistics serve with full parity.
#[test]
fn corrupt_primary_falls_back_to_rotated_checkpoint() {
    let _g = guard();
    let _env = EnvReset::new();
    let scratch = ScratchDir::new("rotated");
    std::env::set_var("MSGP_CKPT_DIR", &scratch.0);
    let data = gen_stress_1d(600, 0.05, 47);
    let server_a = online_server(1_000_000);
    let k = server_a.ingest(data.x.clone(), data.y.clone()).unwrap();
    assert_eq!(k, 600);
    server_a.flush_stream().unwrap();
    let p_a = server_a.predict(vec![1.5]).unwrap();
    server_a.shutdown(); // persists the final statistics as ski.ckpt
    let primary = scratch.0.join("ski.ckpt");
    assert!(primary.exists(), "shutdown checkpoint missing");
    // Simulate the torn-write crash window: the good bytes sit in the
    // rotation slot, the newest file is garbage.
    std::fs::rename(&primary, scratch.0.join("ski.ckpt.1")).unwrap();
    std::fs::write(&primary, b"MSGPCKPT torn mid-write").unwrap();
    let server_b = online_server(1_000_000);
    assert_eq!(
        server_b.metrics.ckpt_restores_total.get(),
        1,
        "fallback restore from the rotation must be recorded"
    );
    server_b.flush_stream().unwrap();
    let p_b = server_b.predict(vec![1.5]).unwrap();
    assert!(
        (p_a.mean - p_b.mean).abs() < 1e-10,
        "rotated restore must serve the checkpointed statistics: {} vs {}",
        p_a.mean,
        p_b.mean
    );
    assert!((p_a.var - p_b.var).abs() < 1e-10, "{} vs {}", p_a.var, p_b.var);
    server_b.shutdown();
}

/// Sharded crash-restore: every worker persists `[own, halo]` at
/// graceful shutdown and replays them on restart — the restored fleet's
/// statistics and served predictions match the original to 1e-10.
#[test]
fn sharded_restart_restores_per_shard_statistics() {
    let _g = guard();
    let _env = EnvReset::new();
    let scratch = ScratchDir::new("sharded");
    std::env::set_var("MSGP_CKPT_DIR", &scratch.0);
    let data = gen_stress_1d(1000, 0.05, 31);
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]);
    let cfg = ShardConfig {
        shards: 2,
        refresh_every: usize::MAX, // only the explicit flush refreshes
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() },
        ..Default::default()
    };
    let probe: Vec<f64> = (0..100).map(|i| -9.0 + 0.18 * i as f64).collect();
    let fleet_a = ShardedTrainer::start(se_kernel(), 0.01, grid.clone(), cfg.clone());
    let applied = fleet_a.ingest_batch(&data.x, &data.y);
    assert!(applied > 900, "interior points must be admitted: {applied}");
    fleet_a.flush();
    let (mean_a, var_a) = fleet_a.predict_batch(&probe);
    let stats_a = fleet_a.owned_stats();
    drop(fleet_a); // graceful shutdown writes ski-shard{0,1}.ckpt
    assert!(scratch.0.join("ski-shard0.ckpt").exists());
    assert!(scratch.0.join("ski-shard1.ckpt").exists());
    let fleet_b = ShardedTrainer::start(se_kernel(), 0.01, grid, cfg);
    // `owned_stats` round-trips every worker FIFO, so by the time it
    // returns each worker has finished its restore replay + publish.
    let stats_b = fleet_b.owned_stats();
    assert_eq!(fleet_b.metrics.ckpt_restores_total.get(), 2, "both shards must restore");
    assert_eq!(fleet_b.metrics.recovering.get(), 0, "recovery gauge must settle back to 0");
    for (s, (a, b)) in stats_a.iter().zip(&stats_b).enumerate() {
        assert_eq!(a.n(), b.n(), "shard {s} point count");
        for (x, y) in a.wty().iter().zip(b.wty()) {
            assert!((x - y).abs() < 1e-12, "shard {s} wty: {x} vs {y}");
        }
    }
    let (mean_b, var_b) = fleet_b.predict_batch(&probe);
    for i in 0..probe.len() {
        assert!(
            (mean_a[i] - mean_b[i]).abs() < 1e-10,
            "mean parity at {}: {} vs {}",
            probe[i],
            mean_a[i],
            mean_b[i]
        );
        assert!(
            (var_a[i] - var_b[i]).abs() < 1e-10,
            "var parity at {}: {} vs {}",
            probe[i],
            var_a[i],
            var_b[i]
        );
    }
}

/// Shard ingest panics are supervised per worker: the batch's acks are
/// dropped (counted as not applied, no hang), the workers restart, and
/// the fleet keeps absorbing afterwards.
#[test]
fn shard_ingest_panics_restart_workers_without_hanging_callers() {
    let _g = guard();
    let _env = EnvReset::new();
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 64)]);
    let cfg = ShardConfig {
        shards: 2,
        refresh_every: usize::MAX,
        msgp: MsgpConfig { n_per_dim: vec![64], n_var_samples: 2, ..Default::default() },
        ..Default::default()
    };
    let fleet = ShardedTrainer::start(se_kernel(), 0.01, grid, cfg);
    let data = gen_stress_1d(200, 0.05, 41);
    fault::configure("shard.ingest=panic").unwrap();
    let applied = fleet.ingest_batch(&data.x[..100], &data.y[..100]);
    assert_eq!(applied, 0, "panicked sub-batches must not be counted as applied");
    assert!(fleet.metrics.worker_restarts[1].get() >= 1, "shard restarts not recorded");
    fault::clear_all();
    // Give the supervised workers time to clear their backoff sleeps.
    std::thread::sleep(std::time::Duration::from_millis(200));
    let applied = fleet.ingest_batch(&data.x[100..], &data.y[100..]);
    assert_eq!(applied, 100, "healed fleet must absorb again");
    fleet.flush();
    let (mean, _) = fleet.predict_batch(&[0.0]);
    assert!(mean[0].is_finite());
}

/// The `/failpoints` HTTP route drives the registry end to end:
/// install, observe hit/fire counters, clear.
#[test]
fn failpoints_route_installs_fires_and_clears() {
    let _g = guard();
    let _env = EnvReset::new();
    let server = online_server(1_000_000);
    let body = server
        .handle_failpoints("/failpoints?set=ingest.batch:sleep(1)@1.0")
        .expect("valid spec");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("armed"), Some(&Json::Bool(true)), "{body}");
    assert!(body.contains("ingest.batch"), "{body}");
    server.ingest(vec![0.5], vec![1.0]).unwrap();
    let status = fault::snapshot();
    let fp = status.iter().find(|s| s.name == "ingest.batch").expect("configured");
    assert!(fp.hits >= 1 && fp.fires >= 1, "hits {} fires {}", fp.hits, fp.fires);
    let body = server.handle_failpoints("/failpoints?clear=1").unwrap();
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("armed"), Some(&Json::Bool(false)), "{body}");
    assert!(!fault::armed());
    server.shutdown();
}
