"""L2 model-graph correctness: the graphs `aot.py` lowers, evaluated in
JAX and compared against independent references (numpy dense algebra)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def keys_np(s):
    t = np.abs(s)
    w1 = (1.5 * t - 2.5) * t * t + 1.0
    w2 = ((-0.5 * t + 2.5) * t - 4.0) * t + 2.0
    return np.where(t < 1.0, w1, np.where(t < 2.0, w2, 0.0))


def dense_w_np(points, m):
    b = len(points)
    w = np.zeros((b, m), dtype=np.float64)
    for r, u in enumerate(points):
        i0 = int(np.clip(np.floor(u) - 1, 0, m - 4))
        for j in range(4):
            w[r, i0 + j] = keys_np(u - (i0 + j))
    return w


class TestPredictGraphs:
    def test_predict_mean_1d_matches_dense(self):
        rng = np.random.default_rng(0)
        m, b = 64, 16
        pts = rng.uniform(2, m - 3, b).astype(np.float32)
        um = rng.normal(size=m).astype(np.float32)
        (got,) = model.predict_mean_1d(jnp.asarray(pts), jnp.asarray(um))
        want = dense_w_np(pts, m) @ um
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_predict_meanvar_1d_variance_formula(self):
        rng = np.random.default_rng(1)
        m, b = 48, 8
        pts = rng.uniform(2, m - 3, b).astype(np.float32)
        um = rng.normal(size=m).astype(np.float32)
        nu = rng.uniform(0.0, 0.8, size=m).astype(np.float32)
        kss, s2 = np.float32(1.3), np.float32(0.05)
        mean, var = model.predict_meanvar_1d(
            jnp.asarray(pts), jnp.asarray(um), jnp.asarray(nu), kss, s2
        )
        w = dense_w_np(pts, m)
        np.testing.assert_allclose(mean, w @ um, rtol=1e-4, atol=1e-4)
        want_var = np.maximum(kss - w @ nu, 0.0) + s2
        np.testing.assert_allclose(var, want_var, rtol=1e-4, atol=1e-4)

    def test_variance_clipped_at_noise_floor(self):
        # Explained variance larger than kss must clip to sigma2, not go
        # negative (Eq. 10's max[0, .]).
        m = 16
        pts = jnp.asarray([5.0, 8.5], jnp.float32)
        um = jnp.zeros((m,), jnp.float32)
        nu = jnp.full((m,), 10.0, jnp.float32)  # hugely over-explained
        _, var = model.predict_meanvar_1d(pts, um, nu, jnp.float32(1.0), jnp.float32(0.01))
        np.testing.assert_allclose(var, [0.01, 0.01], rtol=1e-6)

    def test_predict_meanvar_2d_matches_ref(self):
        rng = np.random.default_rng(2)
        m1, m2, b = 20, 24, 8
        pts = np.stack(
            [rng.uniform(2, m1 - 3, b), rng.uniform(2, m2 - 3, b)], axis=1
        ).astype(np.float32)
        um = rng.normal(size=(m1, m2)).astype(np.float32)
        nu = rng.uniform(0, 0.5, size=(m1, m2)).astype(np.float32)
        mean, var = model.predict_meanvar_2d(
            jnp.asarray(pts), jnp.asarray(um), jnp.asarray(nu),
            jnp.float32(1.0), jnp.float32(0.1),
        )
        want_mean = ref.ski_gather_2d_ref(jnp.asarray(pts), jnp.asarray(um))
        want_expl = ref.ski_gather_2d_ref(jnp.asarray(pts), jnp.asarray(nu))
        np.testing.assert_allclose(mean, want_mean, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            var, np.maximum(1.0 - np.asarray(want_expl), 0.0) + 0.1, rtol=1e-4, atol=1e-4
        )


class TestWhittleLogdet:
    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(4, 128), ell=st.floats(0.5, 8.0), s2=st.floats(5e-2, 1.0))
    def test_matches_dense_circulant_logdet(self, m, ell, s2):
        # Symmetric circulant column from a wrapped SE kernel. The graph
        # clips eigenvalues at zero before shifting (Eq. in section 5.2),
        # so the dense reference does too. sigma2 >= 0.05 keeps f32 FFT
        # rounding from dominating the log at near-zero eigenvalues.
        i = np.arange(m)
        d = np.minimum(i, m - i).astype(np.float64)
        col = np.exp(-0.5 * (d / ell) ** 2).astype(np.float32)
        (got,) = model.whittle_logdet(jnp.asarray(col), jnp.float32(s2))
        c_dense = np.empty((m, m))
        for r in range(m):
            c_dense[r] = np.roll(col, r)
        eig = np.linalg.eigvalsh(c_dense.astype(np.float64))
        want = np.sum(np.log(np.maximum(eig, 0.0) + s2))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=5e-3 * m)


class TestKskiMatvec:
    def test_matches_dense_ski_operator(self):
        rng = np.random.default_rng(3)
        n, m = 64, 32
        a = 64  # next_pow2(2m - 1)
        pts = rng.uniform(2, m - 3, n).astype(np.float32)
        v = rng.normal(size=n).astype(np.float32)
        # SE kernel column and its circulant embedding (wrapped layout).
        ell, sf2, s2 = 3.0, 1.2, 0.07
        col = sf2 * np.exp(-0.5 * (np.arange(m) / ell) ** 2)
        embed = np.zeros(a)
        embed[:m] = col
        for i in range(1, m):
            embed[a - i] = col[i]
        fn = model.make_kski_matvec_1d(m)
        (got,) = fn(
            jnp.asarray(v), jnp.asarray(pts), jnp.asarray(embed.astype(np.float32)),
            jnp.float32(s2),
        )
        # Dense reference: W (sf2 K_UU) W^T v + s2 v.
        w = dense_w_np(pts, m)
        kuu = np.empty((m, m))
        for r in range(m):
            for c in range(m):
                kuu[r, c] = col[abs(r - c)]
        want = w @ (kuu @ (w.T @ v)) + s2 * v
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
