"""L1 kernel correctness: the Pallas SKI gather vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts: the same
numbers the Rust runtime will execute. Hypothesis sweeps shapes, dtypes
and coordinate distributions; fixed tests pin the interpolation
invariants (partition of unity, quadratic reproduction, boundary
clamping).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ski_interp import ski_gather_1d, ski_gather_2d

jax.config.update("jax_enable_x64", False)


def rand_points(rng, b, m, margin=1.5):
    """Coordinates safely inside the grid (stencil never clamps)."""
    return rng.uniform(margin, m - 1 - margin, size=b).astype(np.float32)


class TestSkiGather1D:
    @settings(max_examples=40, deadline=None)
    @given(
        b=st.integers(1, 64),
        m=st.integers(8, 256),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_oracle(self, b, m, seed):
        rng = np.random.default_rng(seed)
        pts = rand_points(rng, b, m)
        grid = rng.normal(size=m).astype(np.float32)
        got = ski_gather_1d(jnp.asarray(pts), jnp.asarray(grid))
        want = ref.ski_gather_1d_ref(jnp.asarray(pts), jnp.asarray(grid))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(b=st.integers(1, 32), m=st.integers(8, 128), seed=st.integers(0, 2**31 - 1))
    def test_matches_dense_w_matmul(self, b, m, seed):
        rng = np.random.default_rng(seed)
        pts = rand_points(rng, b, m)
        grid = rng.normal(size=m).astype(np.float32)
        got = ski_gather_1d(jnp.asarray(pts), jnp.asarray(grid))
        w = ref.dense_w_1d(jnp.asarray(pts), m)
        want = w @ jnp.asarray(grid)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_partition_of_unity(self):
        m = 64
        pts = jnp.linspace(2.0, m - 3.0, 41, dtype=jnp.float32)
        ones = jnp.ones((m,), jnp.float32)
        out = ski_gather_1d(pts, ones)
        np.testing.assert_allclose(out, np.ones(41), rtol=0, atol=1e-6)

    def test_reproduces_quadratics(self):
        m = 64
        xs = jnp.arange(m, dtype=jnp.float32)
        grid = 0.5 * xs**2 - 3.0 * xs + 1.0
        pts = jnp.linspace(2.0, m - 3.0, 37, dtype=jnp.float32)
        out = ski_gather_1d(pts, grid)
        want = 0.5 * pts**2 - 3.0 * pts + 1.0
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-3)

    def test_exact_at_grid_nodes(self):
        m = 32
        rng = np.random.default_rng(0)
        grid = rng.normal(size=m).astype(np.float32)
        pts = jnp.arange(2, m - 2, dtype=jnp.float32)
        out = ski_gather_1d(pts, jnp.asarray(grid))
        np.testing.assert_allclose(out, grid[2 : m - 2], rtol=1e-5, atol=1e-5)

    def test_boundary_clamping_matches_ref(self):
        m = 16
        rng = np.random.default_rng(1)
        grid = rng.normal(size=m).astype(np.float32)
        # Points near/at the boundary where the stencil shifts inward.
        pts = jnp.asarray([0.0, 0.3, 0.9, 14.2, 14.9, 15.0], jnp.float32)
        got = ski_gather_1d(pts, jnp.asarray(grid))
        want = ref.ski_gather_1d_ref(pts, jnp.asarray(grid))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("block", [8, 16, 32])
    def test_blocked_grid_matches_unblocked(self, block):
        b, m = 64, 128
        rng = np.random.default_rng(2)
        pts = jnp.asarray(rand_points(rng, b, m))
        grid = jnp.asarray(rng.normal(size=m).astype(np.float32))
        got = ski_gather_1d(pts, grid, block=block)
        want = ski_gather_1d(pts, grid)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


class TestSkiGather2D:
    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 32),
        m1=st.integers(8, 48),
        m2=st.integers(8, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_oracle(self, b, m1, m2, seed):
        rng = np.random.default_rng(seed)
        pts = np.stack(
            [rand_points(rng, b, m1), rand_points(rng, b, m2)], axis=1
        )
        grid = rng.normal(size=(m1, m2)).astype(np.float32)
        got = ski_gather_2d(jnp.asarray(pts), jnp.asarray(grid))
        want = ref.ski_gather_2d_ref(jnp.asarray(pts), jnp.asarray(grid))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_partition_of_unity(self):
        m1, m2 = 24, 20
        rng = np.random.default_rng(3)
        pts = np.stack(
            [rand_points(rng, 25, m1), rand_points(rng, 25, m2)], axis=1
        )
        ones = jnp.ones((m1, m2), jnp.float32)
        out = ski_gather_2d(jnp.asarray(pts), ones)
        np.testing.assert_allclose(out, np.ones(25), rtol=0, atol=1e-5)

    def test_separable_function_reproduced(self):
        # Bilinear functions are reproduced exactly by the tensor product.
        m1, m2 = 20, 24
        a = jnp.arange(m1, dtype=jnp.float32)[:, None]
        bb = jnp.arange(m2, dtype=jnp.float32)[None, :]
        grid = 2.0 * a - 0.5 * bb + 0.25 * a * bb
        rng = np.random.default_rng(4)
        pts = np.stack(
            [rand_points(rng, 30, m1), rand_points(rng, 30, m2)], axis=1
        )
        out = ski_gather_2d(jnp.asarray(pts), grid)
        pa, pb = pts[:, 0], pts[:, 1]
        want = 2.0 * pa - 0.5 * pb + 0.25 * pa * pb
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-3)
