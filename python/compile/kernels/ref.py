"""Pure-jnp correctness oracle for the SKI interpolation kernel.

Implements the same Keys cubic-convolution gather as `ski_interp.py`
without Pallas — the pytest suite asserts `assert_allclose` between the
two over randomized shapes and inputs (and against a dense-W matmul).
"""

import jax.numpy as jnp


def keys_weight(s):
    """Keys (1981) cubic kernel with a = -1/2."""
    t = jnp.abs(s)
    w1 = (1.5 * t - 2.5) * t * t + 1.0
    w2 = ((-0.5 * t + 2.5) * t - 4.0) * t + 2.0
    return jnp.where(t < 1.0, w1, jnp.where(t < 2.0, w2, 0.0))


def dense_w_1d(points, m):
    """Materialize the dense (B, M) interpolation matrix for 1-D grids."""
    i = jnp.floor(points).astype(jnp.int32)
    i0 = jnp.clip(i - 1, 0, m - 4)  # (B,)
    cols = jnp.arange(m)[None, :]  # (1, M)
    s = points[:, None] - cols.astype(points.dtype)  # (B, M)
    w = keys_weight(s)
    # Zero any weight outside the 4-tap stencil (matters only at clamped
    # boundaries, where the stencil is shifted inward).
    in_stencil = (cols >= i0[:, None]) & (cols < i0[:, None] + 4)
    return jnp.where(in_stencil, w, 0.0)


def ski_gather_1d_ref(points, grid_vec):
    """Reference `W_* grid_vec` (1-D), via explicit 4-tap gather."""
    m = grid_vec.shape[0]
    i = jnp.floor(points).astype(jnp.int32)
    i0 = jnp.clip(i - 1, 0, m - 4)
    acc = jnp.zeros_like(points)
    for j in range(4):
        idx = i0 + j
        acc = acc + keys_weight(points - idx.astype(points.dtype)) * grid_vec[idx]
    return acc


def ski_gather_2d_ref(points, grid_vals):
    """Reference `W_* vec(grid_vals)` (2-D tensor-product weights)."""
    m1, m2 = grid_vals.shape
    ua, ub = points[:, 0], points[:, 1]
    ia0 = jnp.clip(jnp.floor(ua).astype(jnp.int32) - 1, 0, m1 - 4)
    ib0 = jnp.clip(jnp.floor(ub).astype(jnp.int32) - 1, 0, m2 - 4)
    acc = jnp.zeros_like(ua)
    for ja in range(4):
        idxa = ia0 + ja
        wa = keys_weight(ua - idxa.astype(ua.dtype))
        for jb in range(4):
            idxb = ib0 + jb
            wb = keys_weight(ub - idxb.astype(ub.dtype))
            acc = acc + wa * wb * grid_vals[idxa, idxb]
    return acc


def whittle_logdet_ref(col, sigma2):
    """`log|C + sigma2 I|` from a circulant first column, with clipping."""
    eigs = jnp.real(jnp.fft.fft(col))
    return jnp.sum(jnp.log(jnp.maximum(eigs, 0.0) + sigma2))
