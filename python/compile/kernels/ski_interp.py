"""Layer-1 Pallas kernel: batched local cubic-convolution interpolation.

This is MSGP's per-request compute hot-spot (paper section 5.1): a fast
prediction is `W_* v` where `W_*` has 4 (1-D) or 16 (2-D) non-zeros per
row — a weighted gather from a grid vector (`u_mean` for means, `nu_U`
for variances).

Hardware adaptation (DESIGN.md section 3): the batch of test points is
tiled via ``BlockSpec`` so each tile's points and the grid vector live in
VMEM; per tile we compute the four Keys weights per dimension and do a
vectorized gather-multiply-accumulate. The kernel is gather-bound (no MXU
work) — exactly the point of SKI, which replaces dense kernel algebra by
sparse interpolation. ``interpret=True`` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom calls, and the paper's own testbed is a CPU.

Points arrive in *grid units* (continuous index coordinates); the Rust
coordinator converts physical coordinates using the grid's `lo`/`step`
from the artifact manifest.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Keys (1981) cubic convolution coefficient a = -1/2 (the classical
# choice, also used by the Rust engine and ref.py).


def _keys_weight(s):
    """Keys cubic kernel h(s) evaluated elementwise (|s| < 2 support)."""
    t = jnp.abs(s)
    w1 = (1.5 * t - 2.5) * t * t + 1.0  # |s| < 1
    w2 = ((-0.5 * t + 2.5) * t - 4.0) * t + 2.0  # 1 <= |s| < 2
    return jnp.where(t < 1.0, w1, jnp.where(t < 2.0, w2, 0.0))


def _ski_gather_1d_kernel(u_ref, grid_ref, o_ref):
    """One batch tile: o[b] = sum_j h(u[b] - (i0[b]+j)) * grid[i0[b]+j]."""
    u = u_ref[...]  # (B,) continuous grid-unit coords
    g = grid_ref[...]  # (M,) grid vector
    m = g.shape[0]
    i = jnp.floor(u).astype(jnp.int32)
    i0 = jnp.clip(i - 1, 0, m - 4)
    acc = jnp.zeros_like(u)
    for j in range(4):
        idx = i0 + j
        s = u - idx.astype(u.dtype)
        acc = acc + _keys_weight(s) * jnp.take(g, idx, axis=0)
    o_ref[...] = acc


def ski_gather_1d(points, grid_vec, *, block=None):
    """`W_* grid_vec` for 1-D grids via the Pallas kernel.

    Args:
      points: (B,) f32 — test coordinates in grid units.
      grid_vec: (M,) f32 — values on the grid (e.g. `u_mean`).
      block: optional batch tile size (must divide B); defaults to B.

    Returns:
      (B,) f32 interpolated values.
    """
    b = points.shape[0]
    blk = block or b
    assert b % blk == 0, f"block {blk} must divide batch {b}"
    return pl.pallas_call(
        _ski_gather_1d_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), points.dtype),
        grid=(b // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec(grid_vec.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,
    )(points, grid_vec)


def _ski_gather_2d_kernel(u_ref, grid_ref, o_ref):
    """2-D tile: 16-tap tensor-product gather from a (M1, M2) grid."""
    u = u_ref[...]  # (B, 2)
    g = grid_ref[...]  # (M1, M2)
    m1, m2 = g.shape
    gflat = g.reshape(-1)
    ua, ub = u[:, 0], u[:, 1]
    ia0 = jnp.clip(jnp.floor(ua).astype(jnp.int32) - 1, 0, m1 - 4)
    ib0 = jnp.clip(jnp.floor(ub).astype(jnp.int32) - 1, 0, m2 - 4)
    acc = jnp.zeros_like(ua)
    for ja in range(4):
        idxa = ia0 + ja
        wa = _keys_weight(ua - idxa.astype(ua.dtype))
        for jb in range(4):
            idxb = ib0 + jb
            wb = _keys_weight(ub - idxb.astype(ub.dtype))
            acc = acc + wa * wb * jnp.take(gflat, idxa * m2 + idxb, axis=0)
    o_ref[...] = acc


def ski_gather_2d(points, grid_vals, *, block=None):
    """`W_* vec(grid_vals)` for 2-D grids via the Pallas kernel.

    Args:
      points: (B, 2) f32 — test coordinates in grid units per axis.
      grid_vals: (M1, M2) f32 — values on the grid (row-major).
      block: optional batch tile size (must divide B); defaults to B.

    Returns:
      (B,) f32 interpolated values.
    """
    b = points.shape[0]
    blk = block or b
    assert b % blk == 0, f"block {blk} must divide batch {b}"
    return pl.pallas_call(
        _ski_gather_2d_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), points.dtype),
        grid=(b // blk,),
        in_specs=[
            pl.BlockSpec((blk, 2), lambda i: (i, 0)),
            pl.BlockSpec(grid_vals.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,
    )(points, grid_vals)
