"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the Rust `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

One artifact is produced per (graph, batch-bucket) pair; the manifest
(`artifacts/manifest.json`) records shapes and input layouts so the Rust
coordinator can route padded batches to the right executable.

Run: `python -m compile.aot --out-dir ../artifacts` (from python/).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Batch buckets the dynamic batcher pads to (powers of four-ish; small
# buckets keep p99 low at low load, big ones amortize at high load).
BUCKETS = [8, 32, 128, 256]
# Grid sizes compiled for serving.
M_1D = 512
M_2D = (32, 32)


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_entry(fn, args):
    return to_hlo_text(jax.jit(fn).lower(*args))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}

    def emit(name, text, entry):
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = dict(entry)
        entry["name"] = name
        entry["file"] = name + ".hlo.txt"
        manifest["artifacts"].append(entry)
        print(f"wrote {path} ({len(text)} chars)")

    scalar = f32(())

    for b in BUCKETS:
        # 1-D fused mean+variance prediction.
        text = lower_entry(
            model.predict_meanvar_1d,
            (f32((b,)), f32((M_1D,)), f32((M_1D,)), scalar, scalar),
        )
        emit(
            f"predict_meanvar_1d_b{b}",
            text,
            {
                "kind": "predict_meanvar",
                "dim": 1,
                "batch": b,
                "m": M_1D,
                "inputs": ["points[b]", "u_mean[m]", "nu_u[m]", "kss", "sigma2"],
                "outputs": ["mean[b]", "var[b]"],
            },
        )
        # Mean-only (cheaper; used when the request asks for no variance).
        text = lower_entry(model.predict_mean_1d, (f32((b,)), f32((M_1D,))))
        emit(
            f"predict_mean_1d_b{b}",
            text,
            {
                "kind": "predict_mean",
                "dim": 1,
                "batch": b,
                "m": M_1D,
                "inputs": ["points[b]", "u_mean[m]"],
                "outputs": ["mean[b]"],
            },
        )

    # One 2-D bucket set (smaller sweep; 16-tap stencils).
    for b in [32, 128]:
        text = lower_entry(
            model.predict_meanvar_2d,
            (f32((b, 2)), f32(M_2D), f32(M_2D), scalar, scalar),
        )
        emit(
            f"predict_meanvar_2d_b{b}",
            text,
            {
                "kind": "predict_meanvar",
                "dim": 2,
                "batch": b,
                "m": list(M_2D),
                "inputs": ["points[b,2]", "u_mean[m1,m2]", "nu_u[m1,m2]", "kss", "sigma2"],
                "outputs": ["mean[b]", "var[b]"],
            },
        )

    # Spectral log-det (section 5.2) at the serving grid size.
    text = lower_entry(model.whittle_logdet, (f32((M_1D,)), scalar))
    emit(
        "whittle_logdet_m512",
        text,
        {
            "kind": "whittle_logdet",
            "dim": 1,
            "batch": 1,
            "m": M_1D,
            "inputs": ["col[m]", "sigma2"],
            "outputs": ["logdet"],
        },
    )

    # SKI MVM demo graph (cross-validated against the Rust engine).
    n_demo, m_demo, a_demo = 64, 32, 64
    text = lower_entry(
        model.make_kski_matvec_1d(m_demo),
        (f32((n_demo,)), f32((n_demo,)), f32((a_demo,)), scalar),
    )
    emit(
        f"kski_matvec_1d_n{n_demo}_m{m_demo}",
        text,
        {
            "kind": "kski_matvec",
            "dim": 1,
            "batch": n_demo,
            "m": m_demo,
            "embed": a_demo,
            "inputs": ["v[n]", "points[n]", "embed_col[a]", "sigma2"],
            "outputs": ["av[n]"],
        },
    )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
