"""Layer-2 JAX graphs: MSGP's serving-time compute, calling the Layer-1
Pallas kernel, lowered AOT by `aot.py` and executed from Rust via PJRT.

The graphs correspond to the O(1)-prediction paths of paper section 5.1:

* ``predict_mean_1d``  — Eq. 7: `mu_* = W_* u_mean`.
* ``predict_meanvar_1d`` — Eq. 7 + Eq. 10: mean and clipped variance
  `max(0, k_ss - W_* nu_U) (+ sigma^2)` in one fused pass.
* ``predict_meanvar_2d`` — 2-D grid variant (16-tap stencils).
* ``whittle_logdet`` — section 5.2: `1^T log(max(F c, 0) + sigma^2 1)`
  from a circulant first column (used by the serving health-check and as
  an L2 demonstration of the spectral path).

All shapes are static; `aot.py` lowers one artifact per batch bucket.
Python never runs at serving time.
"""

import jax.numpy as jnp

from compile.kernels.ski_interp import ski_gather_1d, ski_gather_2d


def predict_mean_1d(points, u_mean):
    """Fast predictive mean on a 1-D grid (points in grid units)."""
    return (ski_gather_1d(points, u_mean),)


def predict_meanvar_1d(points, u_mean, nu_u, kss, sigma2):
    """Fast predictive mean and observation variance on a 1-D grid.

    Args:
      points: (B,) grid-unit coordinates.
      u_mean: (M,) `sf2 * K_UU W^T alpha` precompute.
      nu_u: (M,) stochastic explained-variance precompute.
      kss: scalar `k(x, x) = sf2`.
      sigma2: scalar noise variance (added for y-space variance).

    Returns:
      (mean (B,), var (B,)).
    """
    mean = ski_gather_1d(points, u_mean)
    explained = ski_gather_1d(points, nu_u)
    var = jnp.maximum(kss - explained, 0.0) + sigma2
    return (mean, var)


def predict_meanvar_2d(points, u_mean, nu_u, kss, sigma2):
    """2-D grid variant of `predict_meanvar_1d` (points: (B, 2))."""
    mean = ski_gather_2d(points, u_mean)
    explained = ski_gather_2d(points, nu_u)
    var = jnp.maximum(kss - explained, 0.0) + sigma2
    return (mean, var)


def whittle_logdet(col, sigma2):
    """`log|C + sigma2 I|` from the circulant first column (clipped)."""
    eigs = jnp.real(jnp.fft.fft(col))
    return (jnp.sum(jnp.log(jnp.maximum(eigs, 0.0) + sigma2)),)


def make_kski_matvec_1d(m):
    """Build a static-M SKI MVM graph:
    `(sf2 W K_UU W^T + sigma2 I) v` on a 1-D grid with `K_UU` applied
    through its circulant-embedding spectrum.

    Demonstrates the L2 training-time compute graph (the Rust engine has
    its own native implementation of the same operation; tests
    cross-validate the two).

    The returned `fn(v, w_points, grid_col, sigma2)` takes:
      v: (N,) vector; w_points: (N,) coordinates in grid units;
      grid_col: (A,) circulant-embedding first column of `sf2 * K_UU`
      (A = power of two >= 2M - 1, wrapped layout); sigma2: scalar.
    """
    from compile.kernels.ref import dense_w_1d

    def fn(v, w_points, grid_col, sigma2):
        a = grid_col.shape[0]
        spectrum = jnp.real(jnp.fft.fft(grid_col))
        w = dense_w_1d(w_points, m)  # (N, M)
        wt_v = w.T @ v  # (M,)
        pad = jnp.zeros((a,), wt_v.dtype).at[:m].set(wt_v)
        prod = jnp.fft.ifft(jnp.fft.fft(pad) * spectrum).real[:m]
        return (w @ prod + sigma2 * v,)

    return fn
