//! `cargo bench --bench fig9_serving` — the HTTP front-door serving
//! sweep. Boots sharded servers behind the real TCP transport, drives
//! the fixed seeded closed-loop predict/ingest mix at two
//! (shards, clients) configs plus an interleaved tracing-on/off
//! overhead measurement, and records p50/p99/p999 and sustained QPS
//! into `BENCH_fig9_serving.json` (under `MSGP_BENCH_DIR`, default
//! `.`). Same entry point as `loadgen --smoke`, so CI and local runs
//! produce the same artifact.

use std::path::Path;

fn main() {
    let dir = std::env::var("MSGP_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    match msgp::bench::loadgen::smoke(Path::new(&dir)) {
        Ok(path) => println!("# recorded -> {}", path.display()),
        Err(e) => {
            eprintln!("fig9_serving failed: {e}");
            std::process::exit(1);
        }
    }
}
