//! `cargo bench --bench fig1_circulant` — regenerates Figure 1 (and, with
//! BENCH_FULL=1, the appendix A.3 sweeps): circulant log-det
//! approximation quality, plus construction/evaluation timing per
//! approximation kind. Timings persist to `BENCH_fig1.json` (see
//! `bench::recorder`); already-recorded configs are skipped.

use std::time::Duration;

use msgp::bench::{bench_fn, bench_header, Record, Recorder};
use msgp::structure::circulant::{circulant_approx, CirculantKind};

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    msgp::bench::experiments::fig1_circulant(full);

    // Timing: building + logdet per approximation at m = 4096.
    println!("\n# circulant construction + logdet timing, m = 4096, covSE ell = 16");
    bench_header();
    let mut rec = Recorder::open("fig1");
    let m = 4096usize;
    let ell = 16.0;
    let col: Vec<f64> = (0..m).map(|i| (-0.5 * (i as f64 / ell).powi(2)).exp()).collect();
    let tail = move |lag: usize| (-0.5 * (lag as f64 / ell).powi(2)).exp();
    for kind in [CirculantKind::Strang, CirculantKind::Chan, CirculantKind::Helgason] {
        let name = format!("circulant/{}/m4096", kind.name());
        let ran = rec.record_if_new(&name, || {
            let stats = bench_fn(&name, Duration::from_millis(200), 1000, || {
                let c = circulant_approx(kind, &col, 0, None);
                std::hint::black_box(c.logdet(0.01));
            });
            println!("{}", stats.line());
            Record::from_stats(&stats)
        });
        if !ran {
            println!("{name:<44} already recorded — skipped");
        }
    }
    let name = "circulant/whittle/m4096";
    let ran = rec.record_if_new(name, || {
        let stats = bench_fn(name, Duration::from_millis(200), 1000, || {
            let c = circulant_approx(CirculantKind::Whittle, &col, 3, Some(&tail));
            std::hint::black_box(c.logdet(0.01));
        });
        println!("{}", stats.line());
        Record::from_stats(&stats)
    });
    if !ran {
        println!("{name:<44} already recorded — skipped");
    }
    // The O(m^2) reference the circulant approach replaces.
    let t = msgp::structure::toeplitz::SymToeplitz::new(col.clone());
    let name = "toeplitz-levinson-logdet/m4096";
    let ran = rec.record_if_new(name, || {
        let stats = bench_fn(name, Duration::from_millis(500), 50, || {
            std::hint::black_box(t.logdet_levinson(0.01));
        });
        println!("{}", stats.line());
        Record::from_stats(&stats)
    });
    if !ran {
        println!("{name:<44} already recorded — skipped");
    }
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    }
}
