//! `cargo bench --bench fig1_circulant` — regenerates Figure 1 (and, with
//! BENCH_FULL=1, the appendix A.3 sweeps): circulant log-det
//! approximation quality, plus construction/evaluation timing per
//! approximation kind.

use std::time::Duration;

use msgp::bench::{bench_fn, bench_header};
use msgp::structure::circulant::{circulant_approx, CirculantKind};

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    msgp::bench::experiments::fig1_circulant(full);

    // Timing: building + logdet per approximation at m = 4096.
    println!("\n# circulant construction + logdet timing, m = 4096, covSE ell = 16");
    bench_header();
    let m = 4096usize;
    let ell = 16.0;
    let col: Vec<f64> = (0..m).map(|i| (-0.5 * (i as f64 / ell).powi(2)).exp()).collect();
    let tail = move |lag: usize| (-0.5 * (lag as f64 / ell).powi(2)).exp();
    for kind in [CirculantKind::Strang, CirculantKind::Chan, CirculantKind::Helgason] {
        let stats = bench_fn(
            &format!("circulant/{}/m4096", kind.name()),
            Duration::from_millis(200),
            1000,
            || {
                let c = circulant_approx(kind, &col, 0, None);
                std::hint::black_box(c.logdet(0.01));
            },
        );
        println!("{}", stats.line());
    }
    let stats = bench_fn(
        "circulant/whittle/m4096",
        Duration::from_millis(200),
        1000,
        || {
            let c = circulant_approx(CirculantKind::Whittle, &col, 3, Some(&tail));
            std::hint::black_box(c.logdet(0.01));
        },
    );
    println!("{}", stats.line());
    // The O(m^2) reference the circulant approach replaces.
    let t = msgp::structure::toeplitz::SymToeplitz::new(col.clone());
    let stats = bench_fn(
        "toeplitz-levinson-logdet/m4096",
        Duration::from_millis(500),
        50,
        || {
            std::hint::black_box(t.logdet_levinson(0.01));
        },
    );
    println!("{}", stats.line());
}
