//! `cargo bench --bench hot_paths` — microbenchmarks of the primitives on
//! the MSGP hot path, used by the performance pass (EXPERIMENTS.md §Perf):
//! FFT, Toeplitz/BCCB MVM, sparse interpolation, one full SKI MVM, one CG
//! training solve, and the end-to-end serving throughput of both engines.
//! Every measurement persists to `BENCH_hot_paths.json`.

use std::time::Duration;

use msgp::bench::{bench_fn, bench_header, BenchStats, Record, Recorder};
use msgp::coordinator::EngineSpec;
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::grid::{Grid, GridAxis};
use msgp::interp::SparseInterp;
use msgp::kernels::{KernelType, ProductKernel};
use msgp::linalg::fft::plan;
use msgp::linalg::C64;
use msgp::structure::bttb::Bccb;
use msgp::structure::toeplitz::SymToeplitz;

fn main() {
    bench_header();
    let mut rec = Recorder::open("hot_paths");
    let mut emit = |stats: &BenchStats| {
        println!("{}", stats.line());
        rec.record(Record::from_stats(stats));
    };
    let quick = Duration::from_millis(300);

    // FFT at the serving grid sizes.
    for &m in &[512usize, 4096, 65536] {
        let p = plan(m);
        let mut buf: Vec<C64> = (0..m).map(|i| C64::new((i as f64).sin(), 0.0)).collect();
        let stats = bench_fn(&format!("fft/pow2/m{m}"), quick, 100_000, || {
            p.forward(&mut buf);
        });
        emit(&stats);
    }
    // Bluestein (non-power-of-two).
    {
        let m = 1000usize;
        let p = plan(m);
        let mut buf: Vec<C64> = (0..m).map(|i| C64::new(i as f64, 0.0)).collect();
        let stats = bench_fn("fft/bluestein/m1000", quick, 100_000, || {
            p.forward(&mut buf);
        });
        emit(&stats);
    }

    // Toeplitz MVM (the inner K_UU multiply).
    for &m in &[1_000usize, 10_000, 100_000] {
        let col: Vec<f64> = (0..m).map(|i| (-0.5 * (i as f64 / 20.0).powi(2)).exp()).collect();
        let t = SymToeplitz::new(col);
        let v: Vec<f64> = (0..m).map(|i| (i as f64 * 0.1).cos()).collect();
        let mut out = vec![0.0; m];
        let mut scratch = Vec::new();
        let stats = bench_fn(&format!("toeplitz-mvm/m{m}"), quick, 10_000, || {
            t.matvec_into(&v, &mut out, &mut scratch);
        });
        emit(&stats);
    }

    // BCCB MVM (2-D grid).
    {
        let shape = [64usize, 64];
        let b = Bccb::whittle(&shape, 2, &|lag: &[f64]| {
            let r2: f64 = lag.iter().map(|l| l * l).sum();
            (-0.5 * r2 / 49.0).exp()
        });
        let v: Vec<f64> = (0..4096).map(|i| (i as f64 * 0.01).sin()).collect();
        let stats = bench_fn("bccb-mvm/64x64", quick, 10_000, || {
            std::hint::black_box(b.matvec(&v));
        });
        emit(&stats);
    }

    // Sparse interpolation (gather + scatter) at serving scale.
    {
        let n = 100_000usize;
        let m = 10_000usize;
        let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
        let data = gen_stress_1d(n, 0.05, 3);
        let w = SparseInterp::build(&data.x, &grid);
        let gv: Vec<f64> = (0..m).map(|i| (i as f64 * 0.001).sin()).collect();
        let nv: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).cos()).collect();
        let mut out_n = vec![0.0; n];
        let mut out_m = vec![0.0; m];
        let stats = bench_fn("interp/W-gather/n1e5", quick, 10_000, || {
            w.matvec_into(&gv, &mut out_n);
        });
        emit(&stats);
        let stats = bench_fn("interp/Wt-scatter/n1e5", quick, 10_000, || {
            w.tmatvec_into(&nv, &mut out_m);
        });
        emit(&stats);
        let stats = bench_fn("interp/build-W/n1e5", quick, 100, || {
            std::hint::black_box(SparseInterp::build(&data.x, &grid));
        });
        emit(&stats);
    }

    // Full SKI MVM + training solve.
    {
        let n = 50_000;
        let m = 10_000;
        let data = gen_stress_1d(n, 0.05, 4);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
        let cfg = MsgpConfig { n_per_dim: vec![m], ..Default::default() };
        let model =
            MsgpModel::fit_with_grid(kernel.clone(), 0.01, data.clone(), grid.clone(), cfg.clone())
                .unwrap();
        let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let stats = bench_fn("ski-mvm/n5e4-m1e4", quick, 1000, || {
            std::hint::black_box(model.mvm_a(&v));
        });
        emit(&stats);
        let stats = bench_fn("train-solve/n5e4-m1e4", Duration::from_secs(2), 20, || {
            std::hint::black_box(
                MsgpModel::fit_with_grid(kernel.clone(), 0.01, data.clone(), grid.clone(), cfg.clone())
                    .unwrap(),
            );
        });
        emit(&stats);
        let stats = bench_fn("lml-grad/n5e4-m1e4", Duration::from_secs(1), 20, || {
            std::hint::black_box(model.lml_grad());
        });
        emit(&stats);
        // Fast predictions.
        let test: Vec<f64> = (0..1000).map(|i| -9.0 + 0.018 * i as f64).collect();
        let stats = bench_fn("predict-mean-fast/1000pts", quick, 10_000, || {
            std::hint::black_box(model.predict_mean(&test));
        });
        emit(&stats);
    }

    // End-to-end serving throughput (both engines).
    println!("\n# serving throughput (20k requests, 4 client threads)");
    let art_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art_dir.join("manifest.json").exists() {
        let (thr, p50, p99, _) = msgp::bench::experiments::serving_benchmark(
            EngineSpec::Pjrt(art_dir),
            20_000,
            4,
        );
        println!("serve/pjrt: {thr:.0} pred/s, p50<={p50}us p99<={p99}us");
        rec.record(
            Record::from_duration("serve/pjrt/20k-4t", Duration::from_micros(p50))
                .with_extra("pred_per_s", thr)
                .with_extra("p99_us", p99 as f64),
        );
    }
    let (thr, p50, p99, _) =
        msgp::bench::experiments::serving_benchmark(EngineSpec::Native, 20_000, 4);
    println!("serve/native: {thr:.0} pred/s, p50<={p50}us p99<={p99}us");
    rec.record(
        Record::from_duration("serve/native/20k-4t", Duration::from_micros(p50))
            .with_extra("pred_per_s", thr)
            .with_extra("p99_us", p99 as f64),
    );
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    }
}
