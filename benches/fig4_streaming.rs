//! `cargo bench --bench fig4_streaming` — streaming subsystem benchmark:
//! ingest throughput (points/s), refresh latency vs n (the O(m log m)
//! claim: refresh cost must *not* grow with n), and staleness (time from
//! an ingest ack to the refreshed snapshot being live). BENCH_FULL=1
//! enables the larger sweep. Per-checkpoint refresh timings persist to
//! `BENCH_fig4.json`.

use msgp::bench::{Record, Recorder};
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::stream::{StreamConfig, StreamTrainer};
use std::time::Instant;

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let total: usize = if full { 500_000 } else { 50_000 };
    let m = 512usize;
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
    let cfg = StreamConfig {
        msgp: MsgpConfig { n_per_dim: vec![m], n_var_samples: 10, ..Default::default() },
        ..Default::default()
    };
    let mut trainer = StreamTrainer::new(kernel, 0.01, grid, cfg);
    let data = gen_stress_1d(total, 0.05, 7);
    let mut rec = Recorder::open("fig4");

    println!("# fig4_streaming: m = {m}, total = {total}");
    println!("# n ingest_pts_per_s refresh_ms mean_iters staleness_ms");
    let bs = 1024;
    let mut next_report = total / 10;
    let mut ingested = 0usize;
    let mut ingest_secs = 0.0f64;
    while ingested < total {
        let hi = (ingested + bs).min(total);
        let t0 = Instant::now();
        trainer.ingest_batch(&data.x[ingested..hi], &data.y[ingested..hi]);
        ingest_secs += t0.elapsed().as_secs_f64();
        ingested = hi;
        if ingested >= next_report {
            next_report += total / 10;
            // Staleness = one refresh + snapshot build (what a live swap
            // costs between an ingest ack and the new model serving).
            let t1 = Instant::now();
            let stats = trainer.refresh();
            let _sm = trainer.serving_model();
            let staleness = t1.elapsed();
            println!(
                "{:>8} {:>12.0} {:>10.2} {:>10} {:>12.2}",
                ingested,
                ingested as f64 / ingest_secs,
                stats.wall.as_secs_f64() * 1e3,
                stats.mean_iters,
                staleness.as_secs_f64() * 1e3,
            );
            rec.record(
                Record::from_duration(&format!("refresh m={m} n={ingested}"), stats.wall)
                    .with_extra("ingest_pts_per_s", ingested as f64 / ingest_secs)
                    .with_extra("mean_iters", stats.mean_iters as f64)
                    .with_extra("staleness_ms", staleness.as_secs_f64() * 1e3),
            );
        }
    }
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    }
}
