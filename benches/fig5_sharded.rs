//! `cargo bench --bench fig5_sharded` — sharded data-parallel scaling:
//! per-shard refresh wall-clock vs a single whole-domain trainer (the
//! ~1/S claim: each shard solves an m/S-sized system on its own core),
//! plus routed ingest throughput. BENCH_FULL=1 enables the larger sweep.
//! Per-config refresh timings persist to `BENCH_fig5.json`.

use msgp::bench::{Record, Recorder};
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::shard::{ShardConfig, ShardedTrainer};
use msgp::stream::{StreamConfig, StreamTrainer};
use std::time::{Duration, Instant};

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let m: usize = if full { 8192 } else { 4096 };
    let n: usize = if full { 300_000 } else { 60_000 };
    let ns = 8usize;
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
    let data = gen_stress_1d(n, 0.05, 7);
    println!("# fig5_sharded: m = {m}, n = {n}, n_s = {ns}, cores = {cores}");
    println!("# config ingest_pts_per_s refresh_wall_ms speedup_vs_single");

    // Single-trainer baseline: one O(m) refresh on one core.
    let mcfg = MsgpConfig { n_per_dim: vec![m], n_var_samples: ns, ..Default::default() };
    let mut single = StreamTrainer::new(
        kernel.clone(),
        0.01,
        grid.clone(),
        StreamConfig { msgp: mcfg.clone(), ..Default::default() },
    );
    let t0 = Instant::now();
    single.ingest_batch(&data.x, &data.y);
    let single_ingest = t0.elapsed().as_secs_f64();
    // Warm the caches once, then time a post-increment refresh (the
    // steady-state cost a live swap pays).
    single.refresh();
    single.ingest_batch(&data.x[..1024], &data.y[..1024]);
    let t1 = Instant::now();
    single.refresh();
    let single_refresh = t1.elapsed().as_secs_f64();
    println!(
        "{:>8} {:>16.0} {:>15.2} {:>17.2}",
        "single",
        n as f64 / single_ingest,
        single_refresh * 1e3,
        1.0
    );
    let mut rec = Recorder::open("fig5");
    rec.record(
        Record::from_duration(
            &format!("refresh single m={m} n={n}"),
            Duration::from_secs_f64(single_refresh),
        )
        .with_extra("ingest_pts_per_s", n as f64 / single_ingest),
    );

    for &s in &[2usize, 4, 8] {
        if s > cores.max(2) {
            break;
        }
        let cfg = ShardConfig {
            shards: s,
            halo: 8,
            blend: 4,
            refresh_every: usize::MAX, // refresh only on flush, so we time it
            msgp: mcfg.clone(),
            ..Default::default()
        };
        let sharded = ShardedTrainer::start(kernel.clone(), 0.01, grid.clone(), cfg);
        let t2 = Instant::now();
        let bs = 4096;
        let mut i = 0;
        while i < n {
            let hi = (i + bs).min(n);
            sharded.ingest_batch(&data.x[i..hi], &data.y[i..hi]);
            i = hi;
        }
        let shard_ingest = t2.elapsed().as_secs_f64();
        sharded.flush(); // cold warm-starts
        sharded.ingest_batch(&data.x[..1024], &data.y[..1024]);
        let t3 = Instant::now();
        sharded.flush(); // all shards refresh concurrently
        let shard_refresh = t3.elapsed().as_secs_f64();
        println!(
            "{:>8} {:>16.0} {:>15.2} {:>17.2}",
            format!("S={s}"),
            n as f64 / shard_ingest,
            shard_refresh * 1e3,
            single_refresh / shard_refresh
        );
        rec.record(
            Record::from_duration(
                &format!("refresh S={s} m={m} n={n}"),
                Duration::from_secs_f64(shard_refresh),
            )
            .with_extra("ingest_pts_per_s", n as f64 / shard_ingest)
            .with_extra("speedup_vs_single", single_refresh / shard_refresh),
        );
    }
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    }
}
