//! `cargo bench --bench obs_overhead` — pins the observability tax on
//! the refresh hot path. Runs the same `refresh_mdomain` workload with
//! tracing disabled (one relaxed atomic load + branch per span site)
//! and enabled (seqlock ring push per span), and records both medians
//! plus their ratio into `BENCH_obs.json` via the bench recorder. The
//! acceptance bar is a < 2% disabled-path regression; the recorded
//! `overhead_ratio_on_off` documents the enabled-path cost too.
//!
//! The same workload also pins the failpoint tax: disarmed sites are
//! one relaxed load, and an *armed-but-inactive* registry (a failpoint
//! configured on a name the refresh path never reaches) must keep the
//! refresh wall-clock ratio at or under 1.02.

use msgp::bench::{Record, Recorder};
use msgp::fault;
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::obs::Tracer;
use msgp::stream::{StreamConfig, StreamTrainer};
use msgp::util::timing::{bench_fn, bench_header};
use msgp::util::Rng;
use std::time::Duration;

fn build_trainer(m: usize, n: usize) -> StreamTrainer {
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-11.0, 11.0, m)]);
    let mcfg = MsgpConfig { n_per_dim: vec![m], n_var_samples: 4, ..Default::default() };
    let mut trainer = StreamTrainer::new(
        kernel,
        0.01,
        grid,
        StreamConfig { msgp: mcfg, ..Default::default() },
    );
    let mut rng = Rng::new(17);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let x = rng.uniform_in(-10.0, 10.0);
        xs.push(x);
        ys.push(msgp::data::stress_fn(x) + 0.05 * rng.normal());
    }
    trainer.ingest_batch(&xs, &ys);
    trainer
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let m = if full { 4096 } else { 1024 };
    let n = if full { 40_000 } else { 8_000 };
    let min_time = Duration::from_millis(if full { 2000 } else { 400 });
    let mut trainer = build_trainer(m, n);
    println!("# obs_overhead: m = {m}, n = {n}, tracing off vs on");
    bench_header();

    Tracer::set_enabled(false);
    let off = bench_fn(&format!("refresh_mdomain m={m} trace=off"), min_time, 200, || {
        let _ = trainer.refresh();
    });
    println!("{}", off.line());

    Tracer::set_enabled(true);
    let on = bench_fn(&format!("refresh_mdomain m={m} trace=on"), min_time, 200, || {
        let _ = trainer.refresh();
    });
    println!("{}", on.line());
    Tracer::set_enabled(false);
    Tracer::clear();

    let ratio = on.median.as_nanos() as f64 / off.median.as_nanos().max(1) as f64;
    println!("# enabled/disabled median ratio = {ratio:.4}");

    // Failpoint tax: arm the registry with an entry no refresh-path
    // site matches, so every `failpoint!` site pays the full armed cost
    // (registry lookup miss) without any action ever firing.
    fault::clear_all();
    fault::configure("bench.inactive=error@0.0").expect("arm inactive failpoint");
    let armed = bench_fn(&format!("refresh_mdomain m={m} failpoints=armed"), min_time, 200, || {
        let _ = trainer.refresh();
    });
    println!("{}", armed.line());
    fault::clear_all();
    let fp_ratio = armed.median.as_nanos() as f64 / off.median.as_nanos().max(1) as f64;
    println!("# armed-but-inactive/disarmed median ratio = {fp_ratio:.4} (budget 1.02)");

    let mut rec = Recorder::open("obs");
    rec.record(Record::from_stats(&off));
    rec.record(Record::from_stats(&on).with_extra("overhead_ratio_on_off", ratio));
    rec.record(Record::from_stats(&armed).with_extra("failpoint_armed_ratio", fp_ratio));
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    } else {
        println!("# recorded -> {:?}", rec.path());
    }
}
