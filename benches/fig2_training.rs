//! `cargo bench --bench fig2_training` — regenerates Figure 2: one
//! marginal-likelihood + derivatives evaluation per method across n and
//! m. BENCH_FULL=1 enables the larger sweeps (n up to 10^6).

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    msgp::bench::experiments::fig2_training(full);
}
