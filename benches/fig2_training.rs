//! `cargo bench --bench fig2_training` — regenerates Figure 2: one
//! marginal-likelihood + derivatives evaluation per method across n and
//! m. BENCH_FULL=1 enables the larger sweeps (n up to 10^6). The total
//! wall-clock persists to `BENCH_fig2.json`; an already-recorded run is
//! skipped (delete the artifact or point MSGP_BENCH_DIR elsewhere to
//! re-measure).

use msgp::bench::{Record, Recorder};
use msgp::util::timing::time_once;

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let mut rec = Recorder::open("fig2");
    let config = format!("fig2_training full={full}");
    let ran = rec.record_if_new(&config, || {
        let ((), wall) = time_once(|| msgp::bench::experiments::fig2_training(full));
        Record::from_duration(&config, wall)
    });
    if !ran {
        println!("# {config}: already recorded in {:?} — skipped", rec.path());
    }
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    }
}
