//! `cargo bench --bench fig7_batched` — the batched-engine speedups:
//!
//! 1. multi-RHS FFT throughput on a 2-D grid, `fftn_batch` (cache-blocked
//!    panels, shared plans) vs the per-line `fftn` reference — the
//!    acceptance target is >= 1.5x;
//! 2. real circulant MVM throughput, `matvec_batch` (two-for-one packing)
//!    vs per-vector `matvec`;
//! 3. streaming refresh wall-clock, the single block-CG solve
//!    (`StreamTrainer::refresh`) vs the historical `n_s + 1` sequential
//!    solves (`StreamTrainer::refresh_sequential`) on the fig4/fig6
//!    skewed-stream workload.
//!
//! BENCH_FULL=1 enables the larger sweep. Per-config timings persist to
//! `BENCH_fig7.json`.

use msgp::bench::{Record, Recorder};
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::linalg::fft::{fftn, fftn_batch, FftScratch, Workspace};
use msgp::linalg::C64;
use msgp::stream::{StreamConfig, StreamTrainer};
use msgp::structure::circulant::Circulant;
use msgp::util::Rng;
use std::time::Instant;

/// Average seconds per call of `f` over `reps` calls (after one warmup).
fn time_per_call(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// A spatially skewed stream (the fig6 workload): two-thirds of the mass
/// in ~15% of the domain.
fn skewed_stream(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let x = if i % 3 == 0 {
            rng.uniform_in(-10.0, 10.0)
        } else {
            rng.uniform_in(-9.5, -6.5)
        };
        xs.push(x);
        ys.push(msgp::data::stress_fn(x) + 0.05 * rng.normal());
    }
    (xs, ys)
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let mut rec = Recorder::open("fig7");

    // --- 1. batched vs per-line multi-dimensional FFT (2-D grid) ---
    let sides: &[usize] = if full { &[64, 128, 256] } else { &[64, 128] };
    let batch = 16usize;
    let reps = if full { 20 } else { 10 };
    println!("# fig7_batched / fftn: batch = {batch} complex 2-D tensors");
    println!("# side per_line_ms batched_ms speedup");
    for &side in sides {
        let shape = [side, side];
        let per: usize = side * side;
        let data: Vec<C64> = (0..batch * per)
            .map(|i| C64::new((i as f64 * 0.37).sin(), (i as f64 * 0.11).cos()))
            .collect();
        let mut buf = data.clone();
        let per_line = time_per_call(reps, || {
            buf.copy_from_slice(&data);
            for item in buf.chunks_exact_mut(per) {
                fftn(item, &shape, false);
            }
        });
        let mut scratch = FftScratch::default();
        let batched = time_per_call(reps, || {
            buf.copy_from_slice(&data);
            fftn_batch(&mut buf, batch, &shape, false, &mut scratch);
        });
        println!(
            "{:>6} {:>12.3} {:>10.3} {:>8.2}",
            side,
            per_line * 1e3,
            batched * 1e3,
            per_line / batched
        );
        rec.record(
            Record::from_duration(
                &format!("fftn_batch side={side} batch={batch}"),
                std::time::Duration::from_secs_f64(batched),
            )
            .with_extra("per_line_ms", per_line * 1e3)
            .with_extra("speedup", per_line / batched),
        );
    }

    // --- 2. two-for-one real circulant MVM ---
    let ms: &[usize] = if full { &[1024, 4096, 16384] } else { &[1024, 4096] };
    let rhs = 8usize;
    println!("# fig7_batched / circulant mvm: {rhs} real RHS");
    println!("# m per_vec_ms batched_ms speedup");
    for &m in ms {
        let col: Vec<f64> = (0..m)
            .map(|i| (-0.5 * (i.min(m - i) as f64 / 16.0).powi(2)).exp())
            .collect();
        let c = Circulant::new(col);
        let block: Vec<f64> = (0..rhs * m).map(|i| (i as f64 * 0.23).sin()).collect();
        let mut out = vec![0.0; rhs * m];
        let per_vec = time_per_call(reps, || {
            for r in 0..rhs {
                let y = c.matvec(&block[r * m..(r + 1) * m]);
                out[r * m..(r + 1) * m].copy_from_slice(&y);
            }
        });
        let mut ws = Workspace::new();
        let batched = time_per_call(reps, || {
            c.matvec_batch(&block, &mut out, &mut ws);
        });
        println!(
            "{:>6} {:>11.3} {:>10.3} {:>8.2}",
            m,
            per_vec * 1e3,
            batched * 1e3,
            per_vec / batched
        );
        rec.record(
            Record::from_duration(
                &format!("circulant_mvm_batch m={m} rhs={rhs}"),
                std::time::Duration::from_secs_f64(batched),
            )
            .with_extra("per_vec_ms", per_vec * 1e3)
            .with_extra("speedup", per_vec / batched),
        );
    }

    // --- 3. block vs sequential m-domain refresh ---
    let sizes: &[usize] = if full { &[1024, 4096] } else { &[256, 1024] };
    let n: usize = if full { 40_000 } else { 8_000 };
    let ns = if full { 8 } else { 6 };
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let (xs, ys) = skewed_stream(n, 7);
    println!("# fig7_batched / refresh: n = {n}, n_s = {ns}, skewed stream, spectral precond");
    println!("# m mode mean_iters var_iters_total block_iters refresh_wall_ms speedup");
    for &m in sizes {
        let build = || {
            let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
            let mut mcfg =
                MsgpConfig { n_per_dim: vec![m], n_var_samples: ns, ..Default::default() };
            mcfg.cg.tol = 1e-8;
            mcfg.cg.max_iter = 4000;
            let mut t = StreamTrainer::new(
                kernel.clone(),
                0.01,
                grid,
                StreamConfig { msgp: mcfg, ..Default::default() },
            );
            t.ingest_batch(&xs, &ys);
            t
        };
        let mut seq_wall = 0.0f64;
        for mode in ["sequential", "block"] {
            let mut trainer = build();
            let t0 = Instant::now();
            let stats = if mode == "sequential" {
                trainer.refresh_sequential()
            } else {
                trainer.refresh()
            };
            let wall = t0.elapsed().as_secs_f64();
            if mode == "sequential" {
                seq_wall = wall;
            }
            println!(
                "{:>6} {:>10} {:>10} {:>15} {:>11} {:>15.2} {:>8.2}",
                m,
                mode,
                stats.mean_iters,
                stats.var_iters_total,
                stats.block_iters,
                wall * 1e3,
                seq_wall / wall
            );
            rec.record(
                Record::from_duration(
                    &format!("refresh m={m} mode={mode}"),
                    std::time::Duration::from_secs_f64(wall),
                )
                .with_extra("mean_iters", stats.mean_iters as f64)
                .with_extra("speedup_vs_sequential", seq_wall / wall),
            );
        }
    }
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    }
}
