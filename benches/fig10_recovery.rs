//! `cargo bench --bench fig10_recovery` — the crash-recovery cost
//! curve. For each grid size `m`: (a) the atomic checkpoint write
//! (encode + tmp + fsync + rename), (b) the validated load
//! (read + checksum + decode), and (c) the full recovery — rebuild a
//! trainer from the checkpointed statistics and replay the refresh
//! that reconstructs every serving cache. Medians land in
//! `BENCH_fig10_recovery.json` via the bench recorder; the `extra`
//! field carries the on-disk checkpoint size so the bytes/cell cost is
//! tracked alongside the wall-clocks.

use msgp::bench::{Record, Recorder};
use msgp::fault::{load, write_atomic, Checkpoint};
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::stream::{StreamConfig, StreamTrainer};
use msgp::util::timing::{bench_fn, bench_header};
use msgp::util::Rng;
use std::time::Duration;

fn build_trainer(m: usize, n: usize) -> StreamTrainer {
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-11.0, 11.0, m)]);
    let mcfg = MsgpConfig { n_per_dim: vec![m], n_var_samples: 4, ..Default::default() };
    let mut trainer = StreamTrainer::new(
        kernel,
        0.01,
        grid,
        StreamConfig { msgp: mcfg, ..Default::default() },
    );
    let mut rng = Rng::new(23);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let x = rng.uniform_in(-10.0, 10.0);
        xs.push(x);
        ys.push(msgp::data::stress_fn(x) + 0.05 * rng.normal());
    }
    trainer.ingest_batch(&xs, &ys);
    trainer
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let sizes: &[usize] = if full { &[256, 1024, 4096, 16384] } else { &[256, 1024, 4096] };
    let n = if full { 40_000 } else { 8_000 };
    let min_time = Duration::from_millis(if full { 1000 } else { 250 });
    let dir = std::env::temp_dir().join(format!("msgp-fig10-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create bench scratch dir");
    println!("# fig10_recovery: checkpoint write / load / restore+replay vs m (n = {n})");
    bench_header();
    let mut rec = Recorder::open("fig10_recovery");

    for &m in sizes {
        let mut trainer = build_trainer(m, n);
        trainer.refresh();
        let ckpt = Checkpoint {
            seq: 1,
            kernel: trainer.kernel.clone(),
            sigma2: trainer.sigma2,
            skis: vec![trainer.ski().clone()],
        };
        let path = dir.join(format!("ski-m{m}.ckpt"));

        let write = bench_fn(&format!("ckpt_write m={m}"), min_time, 200, || {
            write_atomic(&path, &ckpt).expect("checkpoint write");
        });
        println!("{}", write.line());
        let bytes = std::fs::metadata(&path).map(|md| md.len()).unwrap_or(0);

        let read = bench_fn(&format!("ckpt_load m={m}"), min_time, 200, || {
            let c = load(&path).expect("checkpoint load");
            assert_eq!(c.skis.len(), 1);
        });
        println!("{}", read.line());

        // Full recovery: decode + rebuild the trainer + replay the
        // refresh that reconstructs the serving caches from the
        // statistics alone — the restart-to-serving latency.
        let cfg = trainer.cfg.clone();
        let restore = bench_fn(&format!("ckpt_restore_replay m={m}"), min_time, 50, || {
            let c = load(&path).expect("checkpoint load");
            let ski = c.skis.into_iter().next().expect("one accumulator");
            let mut t = StreamTrainer::from_stats(c.kernel, c.sigma2, cfg.clone(), ski);
            let sm = t.serving_model(); // replays the refresh (trainer is dirty)
            assert!(sm.predict_batch(&[0.0]).0[0].is_finite());
        });
        println!("{}", restore.line());

        rec.record(Record::from_stats(&write).with_extra("ckpt_bytes", bytes as f64));
        rec.record(Record::from_stats(&read));
        rec.record(Record::from_stats(&restore).with_extra("n_points", n as f64));
    }

    let _ = std::fs::remove_dir_all(&dir);
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    } else {
        println!("# recorded -> {:?}", rec.path());
    }
}
