//! `cargo bench --bench fig3_prediction` — regenerates Figure 3
//! (prediction runtime) and Figure 4 (fast-vs-slow prediction accuracy).
//! BENCH_FULL=1 enables the larger sweeps.

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    msgp::bench::experiments::fig3_prediction(full);
    println!();
    msgp::bench::experiments::fig4_accuracy(full);
}
