//! `cargo bench --bench fig3_prediction` — regenerates Figure 3
//! (prediction runtime) and Figure 4 (fast-vs-slow prediction accuracy).
//! BENCH_FULL=1 enables the larger sweeps. Wall-clocks persist to
//! `BENCH_fig3.json`; already-recorded sections are skipped.

use msgp::bench::{Record, Recorder};
use msgp::util::timing::time_once;

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let mut rec = Recorder::open("fig3");

    let config = format!("fig3_prediction full={full}");
    let ran = rec.record_if_new(&config, || {
        let ((), wall) = time_once(|| msgp::bench::experiments::fig3_prediction(full));
        Record::from_duration(&config, wall)
    });
    if !ran {
        println!("# {config}: already recorded in {:?} — skipped", rec.path());
    }

    println!();
    let config = format!("fig4_accuracy full={full}");
    let ran = rec.record_if_new(&config, || {
        let ((), wall) = time_once(|| msgp::bench::experiments::fig4_accuracy(full));
        Record::from_duration(&config, wall)
    });
    if !ran {
        println!("# {config}: already recorded in {:?} — skipped", rec.path());
    }
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    }
}
