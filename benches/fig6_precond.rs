//! `cargo bench --bench fig6_precond` — refresh preconditioner
//! comparison on a spatially skewed stream: mean-solve and probe-solve
//! CG iteration counts plus refresh wall-clock for
//! `None | Jacobi | Spectral` (see `solver::Preconditioner`), at a
//! sweep of grid sizes. The iteration count — not the per-MVM cost —
//! dominates refresh latency on ill-conditioned grids, which is exactly
//! where the spectral BCCB inverse earns its O(m log m) application.
//! BENCH_FULL=1 enables the larger sweep. Per-config refresh timings
//! persist to `BENCH_fig6.json`.

use msgp::bench::{Record, Recorder};
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::solver::Preconditioner;
use msgp::stream::{StreamConfig, StreamTrainer};
use msgp::util::Rng;
use std::time::Instant;

/// A spatially skewed stream: two-thirds of the mass in ~15% of the
/// domain, the rest spread across it, so `diag(G)` spans orders of
/// magnitude while every region keeps some coverage.
fn skewed_stream(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let x = if i % 3 == 0 {
            rng.uniform_in(-10.0, 10.0)
        } else {
            rng.uniform_in(-9.5, -6.5)
        };
        xs.push(x);
        ys.push(msgp::data::stress_fn(x) + 0.05 * rng.normal());
    }
    (xs, ys)
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let sizes: &[usize] = if full { &[512, 2048, 8192] } else { &[256, 1024] };
    let n: usize = if full { 40_000 } else { 8_000 };
    let ns = if full { 8 } else { 4 };
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let (xs, ys) = skewed_stream(n, 7);
    println!("# fig6_precond: n = {n}, n_s = {ns}, skewed stream, cg tol = 1e-8");
    println!("# m precond mean_iters var_iters_total refresh_wall_ms speedup_vs_none");
    let mut rec = Recorder::open("fig6");
    for &m in sizes {
        let mut none_wall = 0.0f64;
        for precond in [Preconditioner::None, Preconditioner::Jacobi, Preconditioner::Spectral] {
            let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
            let mut mcfg =
                MsgpConfig { n_per_dim: vec![m], n_var_samples: ns, ..Default::default() };
            mcfg.cg.precondition = precond;
            mcfg.cg.tol = 1e-8;
            mcfg.cg.max_iter = 4000;
            let mut trainer = StreamTrainer::new(
                kernel.clone(),
                0.01,
                grid,
                StreamConfig { msgp: mcfg, ..Default::default() },
            );
            trainer.ingest_batch(&xs, &ys);
            let t0 = Instant::now();
            let stats = trainer.refresh();
            let wall = t0.elapsed().as_secs_f64();
            if precond == Preconditioner::None {
                none_wall = wall;
            }
            println!(
                "{:>6} {:>8} {:>10} {:>15} {:>15.2} {:>15.2}",
                m,
                precond.name(),
                stats.mean_iters,
                stats.var_iters_total,
                wall * 1e3,
                none_wall / wall
            );
            rec.record(
                Record::from_duration(
                    &format!("refresh m={m} precond={}", precond.name()),
                    std::time::Duration::from_secs_f64(wall),
                )
                .with_extra("mean_iters", stats.mean_iters as f64)
                .with_extra("var_iters_total", stats.var_iters_total as f64)
                .with_extra("speedup_vs_none", none_wall / wall),
            );
        }
    }
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    }
}
