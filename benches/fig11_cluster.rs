//! `cargo bench --bench fig11_cluster` — the replication cost curve.
//! Three measurements: (a) `delta_cut` — cutting an additive statistic
//! delta (`diff_ski`) and encoding it as a wire frame, the CPU cost a
//! node pays per ship; (b) `ship_apply` — end-to-end replication
//! latency for one ingest batch across a live 2-node loopback cluster
//! (ingest → cut → TCP → idempotent apply, measured until the peer's
//! replica reflects the batch); (c) `rejoin_catchup` — wall-clock for
//! a killed-and-restarted node to rebind, restore its checkpoint, and
//! leave `recovering` via `SyncRequest` catch-up. Medians land in
//! `BENCH_fig11_cluster.json`; `extra` carries the delta frame size so
//! bytes-per-ship is tracked alongside the wall-clocks.

use msgp::bench::{Record, Recorder};
use msgp::cluster::{diff_ski, ClusterConfig, ClusterNode};
use msgp::fault::{CkptConfig, Frame};
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::shard::ShardPlan;
use msgp::stream::{IncrementalSki, StreamConfig};
use msgp::util::timing::{bench_fn, bench_header};
use msgp::util::Rng;
use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn se_kernel() -> KernelSpec {
    KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0))
}

fn stream_cfg() -> StreamConfig {
    StreamConfig {
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() },
        refresh_every: 1_000_000,
        ..Default::default()
    }
}

fn plan() -> ShardPlan {
    ShardPlan::new(Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]), 6, 4, 2)
}

fn node_cfg(id: usize, peers: Vec<String>, ckpt: Option<&std::path::Path>) -> ClusterConfig {
    let mut cfg = ClusterConfig::new(id, peers);
    cfg.timeout = Duration::from_millis(500);
    cfg.ship_every = 64;
    cfg.ship_ms = 10;
    cfg.hb_ms = 50;
    cfg.ckpt =
        CkptConfig { dir: ckpt.map(|p| p.to_path_buf()), every_points: 512, every_ms: 1_000 };
    cfg
}

fn start_pair(ckpt: Option<&std::path::Path>) -> (Vec<Arc<ClusterNode>>, Vec<String>) {
    let listeners: Vec<TcpListener> =
        (0..2).map(|_| TcpListener::bind("127.0.0.1:0").expect("bind ephemeral")).collect();
    let peers: Vec<String> =
        listeners.iter().map(|l| l.local_addr().expect("local addr").to_string()).collect();
    let nodes = listeners
        .into_iter()
        .enumerate()
        .map(|(id, l)| {
            ClusterNode::start(
                se_kernel(),
                0.01,
                stream_cfg(),
                plan(),
                node_cfg(id, peers.clone(), ckpt),
                Some(l),
            )
            .expect("start cluster node")
        })
        .collect();
    (nodes, peers)
}

fn gen_batch(rng: &mut Rng, k: usize) -> (Vec<f64>, Vec<f64>) {
    let mut xs = Vec::with_capacity(k);
    let mut ys = Vec::with_capacity(k);
    for _ in 0..k {
        let x = rng.uniform_in(-10.0, 10.0);
        xs.push(x);
        ys.push(msgp::data::stress_fn(x) + 0.05 * rng.normal());
    }
    (xs, ys)
}

/// Replicated points visible on `node` (it ingests nothing itself).
fn replica_points(node: &ClusterNode) -> usize {
    node.cluster_summary()
        .get("replicas")
        .and_then(|v| v.as_arr())
        .map(|rows| {
            rows.iter().filter_map(|r| r.get("n").and_then(|n| n.as_f64())).sum::<f64>() as usize
        })
        .unwrap_or(0)
}

fn spin_until(mut cond: impl FnMut() -> bool, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_micros(200));
    }
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let min_time = Duration::from_millis(if full { 1000 } else { 250 });
    println!("# fig11_cluster: delta cut/encode, 2-node ship+apply, rejoin catch-up");
    bench_header();
    let mut rec = Recorder::open("fig11_cluster");

    // (a) Cutting + encoding a delta frame, per grid size.
    let sizes: &[usize] = if full { &[256, 1024, 4096, 16384] } else { &[256, 1024, 4096] };
    for &m in sizes {
        let grid = Grid::new(vec![GridAxis::span(-11.0, 11.0, m)]);
        let mut prev = IncrementalSki::new(grid, 4, 1, 11);
        let mut rng = Rng::new(29);
        let (xs, ys) = gen_batch(&mut rng, 2_000);
        for (x, y) in xs.iter().zip(&ys) {
            prev.ingest(&[*x], *y);
        }
        let mut cur = prev.clone();
        let (xs, ys) = gen_batch(&mut rng, 256);
        for (x, y) in xs.iter().zip(&ys) {
            cur.ingest(&[*x], *y);
        }
        let mut frame_bytes = 0usize;
        let cut = bench_fn(&format!("delta_cut m={m}"), min_time, 500, || {
            let delta = diff_ski(&cur, &prev).expect("same grid is diffable");
            let frame =
                Frame::Delta { origin: 0, shard: 0, epoch: 1, ski: Box::new(delta) }.encode();
            frame_bytes = frame.len();
        });
        println!("{}", cut.line());
        rec.record(Record::from_stats(&cut).with_extra("frame_bytes", frame_bytes as f64));
    }

    // (b) End-to-end ship+apply across a live 2-node loopback cluster.
    {
        let (nodes, _) = start_pair(None);
        spin_until(|| !nodes[0].recovering() && !nodes[1].recovering(), "initial sync");
        let mut rng = Rng::new(31);
        let mut expected = 0usize;
        let batch = 64usize;
        let ship = bench_fn(&format!("ship_apply batch={batch}"), min_time, 200, || {
            let (xs, ys) = gen_batch(&mut rng, batch);
            expected += nodes[0].ingest(&xs, &ys).expect("past initial sync");
            nodes[0].flush();
            spin_until(|| replica_points(&nodes[1]) >= expected, "replica to catch up");
        });
        println!("{}", ship.line());
        rec.record(Record::from_stats(&ship).with_extra("batch", batch as f64));
        for n in &nodes {
            n.shutdown();
        }
    }

    // (c) Kill + rebind + checkpoint restore + SyncRequest catch-up.
    {
        let dir = std::env::temp_dir().join(format!("msgp-fig11-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("create bench scratch dir");
        let (nodes, peers) = start_pair(Some(&dir));
        spin_until(|| !nodes[0].recovering() && !nodes[1].recovering(), "initial sync");
        let n_points = if full { 20_000 } else { 4_000 };
        let mut rng = Rng::new(37);
        let (xs, ys) = gen_batch(&mut rng, n_points);
        let applied = nodes[0].ingest(&xs, &ys).expect("past initial sync")
            + nodes[1].ingest(&xs, &ys).expect("past initial sync");
        assert_eq!(applied, n_points);
        for n in &nodes {
            n.flush();
        }
        spin_until(
            || replica_points(&nodes[0]) + replica_points(&nodes[1]) + applied >= 2 * n_points,
            "steady-state replication",
        );
        let mut node1 = Some(nodes[1].clone());
        let rejoin = bench_fn(&format!("rejoin_catchup n={n_points}"), min_time, 10, || {
            let old = node1.take().expect("node 1 handle");
            old.shutdown();
            let fresh = ClusterNode::start(
                se_kernel(),
                0.01,
                stream_cfg(),
                plan(),
                node_cfg(1, peers.clone(), Some(&dir)),
                None, // re-binds its old address
            )
            .expect("restart node 1");
            spin_until(|| !fresh.recovering(), "rejoin catch-up");
            node1 = Some(fresh);
        });
        println!("{}", rejoin.line());
        rec.record(Record::from_stats(&rejoin).with_extra("n_points", n_points as f64));
        nodes[0].shutdown();
        if let Some(n) = node1 {
            n.shutdown();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    } else {
        println!("# recorded -> {:?}", rec.path());
    }
}
