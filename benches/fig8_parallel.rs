//! `cargo bench --bench fig8_parallel` — the in-tree parallel execution
//! layer and the true real-FFT half-spectrum:
//!
//! 1. batched FFT throughput vs thread count (`fftn_batch` on a 2-D
//!    grid, the pool's line-chunk / panel fan-out);
//! 2. streaming block-refresh wall-clock vs thread count on a grid with
//!    m >= 4096 — the acceptance target is >= 1.5x at 4 threads;
//! 3. rfft half-spectrum vs full complex transform time for the batched
//!    real-spectrum apply (even last axis), plus the half-transform op
//!    counter delta.
//!
//! Results are identical at every thread count (pinned by the test
//! suite); this bench measures wall-clock only. BENCH_FULL=1 enables
//! the larger sweep. Per-config timings persist to `BENCH_fig8.json`.

use msgp::bench::{Record, Recorder};
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::linalg::fft::{
    apply_real_spectrum_batch, fftn, fftn_batch, rfft_half_lines_total, FftScratch, Workspace,
};
use msgp::linalg::C64;
use msgp::parallel::{self, ParallelConfig};
use msgp::stream::{StreamConfig, StreamTrainer};
use msgp::util::Rng;
use std::time::Instant;

/// Average seconds per call of `f` over `reps` calls (after one warmup).
fn time_per_call(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// A spatially skewed stream (the fig6/fig7 workload).
fn skewed_stream(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let x = if i % 3 == 0 {
            rng.uniform_in(-10.0, 10.0)
        } else {
            rng.uniform_in(-9.5, -6.5)
        };
        xs.push(x);
        ys.push(msgp::data::stress_fn(x) + 0.05 * rng.normal());
    }
    (xs, ys)
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let thread_sweep: &[usize] = if full { &[1, 2, 4, 8] } else { &[1, 2, 4] };
    let mut rec = Recorder::open("fig8");

    // --- 1. batched FFT throughput vs thread count (2-D grid) ---
    let side: usize = if full { 256 } else { 128 };
    let batch = 16usize;
    let reps = if full { 20 } else { 10 };
    let shape = [side, side];
    let per = side * side;
    let data: Vec<C64> = (0..batch * per)
        .map(|i| C64::new((i as f64 * 0.37).sin(), (i as f64 * 0.11).cos()))
        .collect();
    let mut buf = data.clone();
    println!("# fig8_parallel / fftn_batch: {batch} x {side}x{side} complex tensors");
    println!("# threads batched_ms speedup_vs_1t");
    let mut base_ms = 0.0f64;
    for &t in thread_sweep {
        parallel::configure(ParallelConfig { threads: t });
        let mut scratch = FftScratch::default();
        let secs = time_per_call(reps, || {
            buf.copy_from_slice(&data);
            fftn_batch(&mut buf, batch, &shape, false, &mut scratch);
        });
        if t == 1 {
            base_ms = secs * 1e3;
        }
        println!("{:>8} {:>10.3} {:>12.2}", t, secs * 1e3, base_ms / (secs * 1e3));
        rec.record(
            Record::from_duration(
                &format!("fftn_batch threads={t} side={side}"),
                std::time::Duration::from_secs_f64(secs),
            )
            .with_extra("speedup_vs_1t", base_ms / (secs * 1e3)),
        );
    }

    // --- 2. block-refresh wall-clock vs thread count (m >= 4096) ---
    let m: usize = if full { 8192 } else { 4096 };
    let n: usize = if full { 60_000 } else { 30_000 };
    let ns = 8usize;
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let (xs, ys) = skewed_stream(n, 7);
    println!("# fig8_parallel / refresh: m = {m}, n = {n}, n_s = {ns}, spectral precond");
    println!("# threads block_iters refresh_wall_ms speedup_vs_1t");
    let mut base_refresh = 0.0f64;
    for &t in thread_sweep {
        parallel::configure(ParallelConfig { threads: t });
        let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
        let mut mcfg = MsgpConfig { n_per_dim: vec![m], n_var_samples: ns, ..Default::default() };
        mcfg.cg.tol = 1e-8;
        mcfg.cg.max_iter = 4000;
        let mut trainer = StreamTrainer::new(
            kernel.clone(),
            0.01,
            grid,
            StreamConfig { msgp: mcfg, ..Default::default() },
        );
        trainer.ingest_batch(&xs, &ys);
        let t0 = Instant::now();
        let stats = trainer.refresh();
        let wall = t0.elapsed().as_secs_f64() * 1e3;
        if t == 1 {
            base_refresh = wall;
        }
        println!(
            "{:>8} {:>11} {:>15.2} {:>13.2}",
            t,
            stats.block_iters,
            wall,
            base_refresh / wall
        );
        rec.record(
            Record::from_duration(
                &format!("refresh threads={t} m={m}"),
                std::time::Duration::from_secs_f64(wall / 1e3),
            )
            .with_extra("block_iters", stats.block_iters as f64)
            .with_extra("speedup_vs_1t", base_refresh / wall),
        );
    }

    // --- 3. rfft half-spectrum vs full complex transform ---
    parallel::configure(ParallelConfig { threads: 1 }); // isolate the algorithmic win
    let ms: &[usize] = if full { &[4096, 16384] } else { &[1024, 4096] };
    let rows = 8usize;
    println!("# fig8_parallel / rfft: {rows} real RHS, serial (1 thread)");
    println!("# m full_complex_ms rfft_half_ms speedup half_lines");
    for &m in ms {
        let spec: Vec<f64> = (0..m)
            .map(|i| (-0.5 * (i.min(m - i) as f64 / 16.0).powi(2)).exp() + 0.1)
            .collect();
        let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.23).sin()).collect();
        let mut out = vec![0.0; rows * m];
        // Full-complex reference: one full-length transform pair per row.
        let full_ms = time_per_call(reps, || {
            for r in 0..rows {
                let mut cbuf: Vec<C64> =
                    block[r * m..(r + 1) * m].iter().map(|&v| C64::real(v)).collect();
                fftn(&mut cbuf, &[m], false);
                for (z, &e) in cbuf.iter_mut().zip(&spec) {
                    *z = z.scale(e);
                }
                fftn(&mut cbuf, &[m], true);
                for (o, z) in out[r * m..(r + 1) * m].iter_mut().zip(&cbuf) {
                    *o = z.re;
                }
            }
        });
        let mut ws = Workspace::new();
        let before = rfft_half_lines_total();
        let rfft_ms = time_per_call(reps, || {
            apply_real_spectrum_batch(&block, &mut out, &[m], &spec, |e| e, &mut ws);
        });
        let half_lines = rfft_half_lines_total() - before;
        println!(
            "{:>6} {:>15.3} {:>12.3} {:>8.2} {:>10}",
            m,
            full_ms * 1e3,
            rfft_ms * 1e3,
            full_ms / rfft_ms,
            half_lines
        );
        rec.record(
            Record::from_duration(
                &format!("rfft_half m={m} rows={rows}"),
                std::time::Duration::from_secs_f64(rfft_ms),
            )
            .with_extra("full_complex_ms", full_ms * 1e3)
            .with_extra("speedup", full_ms / rfft_ms)
            .with_extra("half_lines", half_lines as f64),
        );
    }
    parallel::configure(ParallelConfig { threads: 0 });
    if let Err(e) = rec.save() {
        eprintln!("failed to save {:?}: {e}", rec.path());
    }
}
