//! Quickstart: train MSGP on the paper's 1-D stress function, learn the
//! hyperparameters by marginal-likelihood ascent, and make fast O(1)
//! predictions with uncertainty.
//!
//! Run: `cargo run --release --example quickstart`

use msgp::data::{gen_stress_1d, smae, stress_fn};
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::kernels::{KernelType, ProductKernel};

fn main() -> anyhow::Result<()> {
    // 1. Data: n noisy samples of sin(x) exp(-x^2/50), x ~ U[-10, 10].
    let n = 5_000;
    let data = gen_stress_1d(n, 0.1, 42);

    // 2. Model: SE kernel, m = 1024 inducing points on a grid (note
    //    m ~ n/5 — far beyond what classical inducing-point methods
    //    support), Whittle circulant log-det.
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 0.5, 0.5));
    let cfg = MsgpConfig { n_per_dim: vec![1024], ..Default::default() };
    let mut model = MsgpModel::fit(kernel, 0.05, data, cfg)?;
    println!(
        "fitted: n = {}, m = {}, CG iters = {}, initial LML = {:.1}",
        model.n(),
        model.m(),
        model.last_cg.iters,
        model.lml()
    );

    // 3. Learn hyperparameters (lengthscale, signal variance, noise).
    let trace = model.train(30, 0.1)?;
    println!(
        "trained 30 Adam steps: LML {:.1} -> {:.1}; ell = {:.3}, sigma2 = {:.4}",
        trace[0],
        model.lml(),
        match &model.kernel {
            KernelSpec::Product(k) => k.ell(0),
            _ => unreachable!(),
        },
        model.sigma2
    );

    // 4. Fast predictions (O(1) per point) with uncertainty.
    let test = gen_stress_1d(1_000, 0.0, 7);
    let mean = model.predict_mean(&test.x);
    let var = model.predict_var(&test.x);
    println!("test SMAE = {:.4}", smae(&mean, &test.y));

    // 5. Show a few predictions vs ground truth.
    println!("{:>8} {:>10} {:>10} {:>10}", "x", "truth", "mean", "std");
    for i in (0..test.n()).step_by(200) {
        let x = test.x[i];
        println!(
            "{:>8.3} {:>10.4} {:>10.4} {:>10.4}",
            x,
            stress_fn(x),
            mean[i],
            var[i].sqrt()
        );
    }
    Ok(())
}
