use msgp::coordinator::{BatcherConfig, EngineSpec, Server, ServingModel};
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::kernels::{KernelType, ProductKernel};
use std::time::Instant;

fn main() {
    let data = gen_stress_1d(2000, 0.05, 1);
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let cfg = MsgpConfig { n_per_dim: vec![512], n_var_samples: 5, ..Default::default() };
    let mut model = MsgpModel::fit(kernel, 0.01, data, cfg).unwrap();
    let sm = ServingModel::from_msgp(&mut model);
    // Direct native batch cost:
    let t0 = Instant::now();
    for _ in 0..1000 { std::hint::black_box(sm.predict_batch(&[0.5, 1.0, -2.0, 3.0])); }
    println!("native predict_batch(4): {:?}/call", t0.elapsed() / 1000);
    // Through the server, single-threaded closed loop:
    let server = Server::start(sm, EngineSpec::Native, BatcherConfig::default());
    let t0 = Instant::now();
    for i in 0..2000 { server.predict(vec![(i % 19) as f64 - 9.0]).unwrap(); }
    println!("server round-trip (1 client): {:?}/call", t0.elapsed() / 2000);
    println!("metrics: {}", server.metrics.summary());
}
