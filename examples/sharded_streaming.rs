//! Sharded streaming walkthrough: partition the inducing grid into
//! spatial shards, stream observations through a sharded coordinator
//! while per-shard trainers refresh in parallel, then inspect the shard
//! layout, check a seam, and fold the statistics into one global
//! snapshot.
//!
//! `cargo run --release --example sharded_streaming`

use msgp::coordinator::{BatcherConfig, Server};
use msgp::data::{gen_stress_1d, stress_fn};
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::shard::{ShardConfig, ShardedTrainer};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2).min(4);
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 512)]);
    let cfg = ShardConfig {
        shards,
        halo: 8,
        blend: 4,
        refresh_every: 2048,
        msgp: MsgpConfig { n_per_dim: vec![512], n_var_samples: 8, ..Default::default() },
        ..Default::default()
    };
    let trainer = ShardedTrainer::start(kernel, 0.01, grid.clone(), cfg);
    let seam_x = grid.axes[0].coord(trainer.plan().cuts()[1]);
    println!("plan:\n{}", trainer.summary());
    let server = Server::start_sharded(trainer, BatcherConfig::default());

    // Stream 20k observations; each shard refreshes + hot-swaps its own
    // slot every `refresh_every` points, independently of the others.
    let data = gen_stress_1d(20_000, 0.05, 11);
    let bs = 500;
    let t0 = Instant::now();
    for c in 0..data.y.len() / bs {
        let lo = c * bs;
        let hi = lo + bs;
        server.ingest(data.x[lo..hi].to_vec(), data.y[lo..hi].to_vec())?;
        if (c + 1) % 10 == 0 {
            let p = server.predict(vec![seam_x])?;
            println!(
                "n = {:>6}:  seam mean {:+.4}  var {:.4}   (truth {:+.4})",
                (c + 1) * bs,
                p.mean,
                p.var,
                stress_fn(seam_x)
            );
        }
    }
    let ingest_wall = t0.elapsed();
    server.flush_stream()?;

    // Seam continuity: sample finely across the first shard boundary.
    let mut max_jump = 0.0f64;
    let mut prev = f64::NAN;
    let mut x = seam_x - 0.5;
    while x <= seam_x + 0.5 {
        let p = server.predict(vec![x])?;
        if prev.is_finite() {
            max_jump = max_jump.max((p.mean - prev).abs());
        }
        prev = p.mean;
        x += 0.01;
    }
    println!("max step across the seam (dx = 0.01): {max_jump:.5}");

    // The additive merge: whole-domain snapshot from per-shard stats.
    let trainer = server.shard_trainer().expect("sharded server");
    let merged = trainer.merged_stats();
    println!(
        "merged stats: n = {}, weight = {:.1}, m = {}",
        merged.n(),
        merged.weight(),
        merged.m()
    );
    println!(
        "ingest throughput: {:.0} points/s across {shards} shards",
        data.y.len() as f64 / ingest_wall.as_secs_f64()
    );
    println!("shards:\n{}", server.shards_summary().unwrap());
    println!("metrics: {}", server.metrics.summary());
    server.shutdown();
    Ok(())
}
