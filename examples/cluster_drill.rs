//! Cluster drill with real processes and a real `SIGKILL`:
//!
//! ```sh
//! cargo run --release --example cluster_drill
//! ```
//!
//! The parent re-executes itself three times (`--node`, identity via
//! the same `MSGP_PEERS`/`MSGP_NODE_ID` env a production deployment
//! would use), each child running a [`msgp::cluster::ClusterNode`]
//! behind its own HTTP front door. The parent streams observations to
//! all three doors (each node keeps its stripe), `SIGKILL`s node 2
//! mid-stream, keeps streaming to the survivors, restarts node 2 on
//! the same address (checkpoint restore + `SyncRequest` catch-up),
//! re-sends the segment its stripe missed, finishes the stream, and
//! verifies every door's `/predict` against a single-process merge of
//! the identical stream to 1e-8. Prints `CLUSTER PARITY OK` on
//! success — the CI chaos job greps for it.

use msgp::bench::loadgen::HttpClient;
use msgp::cluster::{ClusterConfig, ClusterNode};
use msgp::coordinator::{HttpConfig, HttpServer, Server};
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::shard::{merge_owned, ShardPlan};
use msgp::stream::{IncrementalSki, StreamConfig, StreamTrainer};
use msgp::util::json::Json;
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::Arc;
use std::time::{Duration, Instant};

const N: usize = 900;
const BATCH: usize = 100;
const NODES: usize = 3;

fn se_kernel() -> KernelSpec {
    KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0))
}

fn stream_cfg() -> StreamConfig {
    StreamConfig {
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() },
        refresh_every: 1_000_000, // models publish on flush, not cadence
        ..Default::default()
    }
}

fn plan() -> ShardPlan {
    ShardPlan::new(Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]), 6, 4, 2)
}

/// Child mode: the cluster node + front door a deployment would run —
/// membership and knobs from the environment, parked until killed.
fn serve_node() {
    let cfg = match ClusterConfig::from_env() {
        Some(Ok(cfg)) => cfg,
        other => {
            eprintln!("cluster_drill --node needs valid MSGP_PEERS env, got {other:?}");
            std::process::exit(2);
        }
    };
    let http_addr = std::env::var("MSGP_DRILL_HTTP").unwrap_or_else(|_| "127.0.0.1:0".into());
    let node = match ClusterNode::start(se_kernel(), 0.01, stream_cfg(), plan(), cfg, None) {
        Ok(node) => node,
        Err(e) => {
            eprintln!("cluster node failed to start: {e}");
            std::process::exit(1);
        }
    };
    let server = Arc::new(Server::start_cluster(node));
    match HttpServer::bind(server, &http_addr, HttpConfig::default()) {
        Ok(http) => {
            println!("node serving on http://{}", http.local_addr());
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("front door failed to bind {http_addr}: {e}");
            std::process::exit(1);
        }
    }
}

/// Reserve a distinct loopback port by binding and dropping. The tiny
/// reuse race is acceptable for a drill that owns the whole box.
fn free_addr() -> String {
    let l = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    l.local_addr().expect("local addr").to_string()
}

fn spawn_node(exe: &PathBuf, id: usize, peers: &str, http: &str, ckpt: &PathBuf) -> Child {
    Command::new(exe)
        .arg("--node")
        .env("MSGP_PEERS", peers)
        .env("MSGP_NODE_ID", id.to_string())
        .env("MSGP_PEER_SHIP_EVERY", "48")
        .env("MSGP_PEER_SHIP_MS", "25")
        .env("MSGP_PEER_HB_MS", "50")
        .env("MSGP_PEER_TIMEOUT_MS", "500")
        .env("MSGP_DRILL_HTTP", http)
        .env("MSGP_CKPT_DIR", ckpt)
        .env("MSGP_CKPT_EVERY_POINTS", "64")
        .env("MSGP_CKPT_EVERY_MS", "500")
        .spawn()
        .expect("spawn cluster node")
}

fn get_json(client: &mut HttpClient, path: &str) -> Option<Json> {
    match client.request("GET", path, None) {
        Ok((200, body)) => Json::parse(&body).ok(),
        _ => None,
    }
}

/// The door is up once `/healthz` answers at all — it reports 503 with
/// a JSON body while the node is still catching up, which is reachable,
/// just not yet healthy.
fn door_up(client: &mut HttpClient) -> bool {
    client.request("GET", "/healthz", None).is_ok()
}

/// Points visible on this node: owned accumulators plus replicas.
fn total_points(client: &mut HttpClient) -> usize {
    let Some(doc) = get_json(client, "/cluster") else { return 0 };
    let count = |key: &str| -> f64 {
        doc.get(key)
            .and_then(|v| v.as_arr())
            .map(|rows| rows.iter().filter_map(|r| r.get("n").and_then(|n| n.as_f64())).sum())
            .unwrap_or(0.0)
    };
    (count("owned") + count("replicas")) as usize
}

fn recovering(client: &mut HttpClient) -> Option<bool> {
    let doc = get_json(client, "/cluster")?;
    match doc.get("recovering") {
        Some(Json::Bool(b)) => Some(*b),
        _ => None,
    }
}

fn wait_until(mut cond: impl FnMut() -> bool, what: &str, secs: u64) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while Instant::now() < deadline {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    eprintln!("DRILL FAILED: timed out waiting for {what}");
    std::process::exit(1);
}

fn ingest(client: &mut HttpClient, xs: &[f64], ys: &[f64]) -> usize {
    let body = Json::obj(vec![
        ("xs", Json::Arr(xs.iter().map(|&v| Json::Num(v)).collect())),
        ("ys", Json::Arr(ys.iter().map(|&v| Json::Num(v)).collect())),
    ])
    .to_string();
    match client.request("POST", "/ingest", Some(&body)) {
        Ok((200, resp)) => Json::parse(&resp)
            .ok()
            .and_then(|d| d.get("applied").and_then(|v| v.as_f64()))
            .unwrap_or(0.0) as usize,
        other => {
            eprintln!("DRILL FAILED: ingest rejected: {other:?}");
            std::process::exit(1);
        }
    }
}

fn flush(client: &mut HttpClient) {
    let (status, _) = client
        .request("POST", "/ingest", Some("{\"flush\": true}"))
        .expect("flush request");
    assert_eq!(status, 200, "flush must succeed");
}

/// The single-process truth: per-shard accumulators with the cluster's
/// seeds, each point ingested once into its owner shard, merged.
fn reference_predict(xs: &[f64], ys: &[f64], probe: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let plan = plan();
    let scfg = stream_cfg();
    let ns = scfg.msgp.n_var_samples.max(1);
    let seed = scfg.msgp.seed;
    let mut parts: Vec<IncrementalSki> = (0..plan.shards())
        .map(|s| IncrementalSki::new(plan.local_grid(s), ns, 1, seed ^ (2 * s as u64)))
        .collect();
    for (i, &y) in ys.iter().enumerate() {
        let x = &xs[i..i + 1];
        parts[plan.owner_of(x)].ingest(x, y);
    }
    let merged = merge_owned(plan.global().clone(), seed, &parts);
    let mut trainer = StreamTrainer::from_stats(se_kernel(), 0.01, scfg, merged);
    trainer.serving_model().predict_batch(probe)
}

fn main() {
    let mut args = std::env::args().skip(1);
    if let Some(flag) = args.next() {
        if flag == "--node" {
            serve_node();
        }
        eprintln!("unknown argument `{flag}` (this binary re-executes itself with --node)");
        std::process::exit(2);
    }

    let dir: PathBuf =
        std::env::temp_dir().join(format!("msgp-cluster-drill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create checkpoint dir");
    let exe = std::env::current_exe().expect("current_exe");
    let peer_addrs: Vec<String> = (0..NODES).map(|_| free_addr()).collect();
    let http_addrs: Vec<String> = (0..NODES).map(|_| free_addr()).collect();
    let peers = peer_addrs.join(",");
    println!("membership: {peers}");

    let mut children: Vec<Child> =
        (0..NODES).map(|i| spawn_node(&exe, i, &peers, &http_addrs[i], &dir)).collect();
    let mut doors: Vec<HttpClient> = http_addrs
        .iter()
        .map(|a| HttpClient::new(a.parse::<SocketAddr>().expect("drill http addr")))
        .collect();
    for (i, door) in doors.iter_mut().enumerate() {
        wait_until(|| door_up(door), &format!("node {i} front door"), 30);
        // Clients gate ingest on the recovery flag (docs/CLUSTER.md):
        // a node still syncing may adopt peer snapshots of its shards.
        wait_until(|| recovering(door) == Some(false), &format!("node {i} initial sync"), 30);
    }

    let data = gen_stress_1d(N, 0.05, 77);
    let fan = |doors: &mut [HttpClient], lo: usize, hi: usize| -> usize {
        doors.iter_mut().map(|d| ingest(d, &data.x[lo..hi], &data.y[lo..hi])).sum()
    };

    // Segment A: everyone up.
    let mut accepted = 0;
    for c in 0..3 {
        accepted += fan(&mut doors, c * BATCH, (c + 1) * BATCH);
    }
    for d in doors.iter_mut() {
        flush(d);
    }
    for (i, d) in doors.iter_mut().enumerate() {
        wait_until(|| total_points(d) == 300, &format!("segment A on node {i}"), 20);
    }

    // Kill node 2 without warning, mid-replication-stream.
    children[2].kill().expect("SIGKILL node 2");
    let _ = children[2].wait();
    println!("node 2 killed mid-stream");

    // Segment B: survivors only — their stripes land, node 2's is lost.
    let mut seg_b = 0;
    for c in 3..6 {
        seg_b += fan(&mut doors[..2], c * BATCH, (c + 1) * BATCH);
    }
    assert!(seg_b < 300, "the dead node's stripe must be missing, got {seg_b}");
    // Survivors answer instantly throughout — no hangs, no errors.
    let (status, _) = doors[0]
        .request("POST", "/predict", Some("{\"points\": [0.5]}"))
        .expect("predict while a peer is down");
    assert_eq!(status, 200, "serving must continue with a peer down");

    // Restart node 2 on its old address: checkpoint restore + catch-up.
    children[2] = spawn_node(&exe, 2, &peers, &http_addrs[2], &dir);
    wait_until(|| door_up(&mut doors[2]), "node 2 restart", 30);
    wait_until(|| recovering(&mut doors[2]) == Some(false), "node 2 catch-up", 30);
    println!("node 2 rejoined and caught up");

    // Re-send the segment its stripe missed (it keeps exactly its own
    // points, so nothing is double-counted), then finish the stream.
    let missed = ingest(&mut doors[2], &data.x[300..600], &data.y[300..600]);
    assert_eq!(seg_b + missed, 300, "resend must recover exactly the lost stripe");
    accepted += seg_b + missed;
    for c in 6..9 {
        accepted += fan(&mut doors, c * BATCH, (c + 1) * BATCH);
    }
    assert_eq!(accepted, N, "every point must land on exactly one node");
    for d in doors.iter_mut() {
        flush(d);
    }
    for (i, d) in doors.iter_mut().enumerate() {
        wait_until(|| total_points(d) == N, &format!("full replication on node {i}"), 30);
    }
    for d in doors.iter_mut() {
        flush(d); // publish the final replica view synchronously
    }

    // Every door must match the single-process merge of the identical
    // stream — including the door that was killed and restarted.
    let probe: Vec<f64> = (0..60).map(|i| -9.0 + 0.3 * i as f64).collect();
    let (want_mean, want_var) = reference_predict(&data.x, &data.y, &probe);
    let body = Json::obj(vec![(
        "points",
        Json::Arr(probe.iter().map(|&v| Json::Num(v)).collect()),
    )])
    .to_string();
    let mut worst = 0.0f64;
    for (i, d) in doors.iter_mut().enumerate() {
        let (status, resp) = d.request("POST", "/predict", Some(&body)).expect("parity predict");
        assert_eq!(status, 200, "node {i} parity predict");
        let doc = Json::parse(&resp).expect("predict response parses");
        let grab = |key: &str| -> Vec<f64> {
            doc.get(key)
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or_default()
        };
        let (mean, var) = (grab("mean"), grab("var"));
        assert_eq!(mean.len(), probe.len(), "node {i} mean length");
        for k in 0..probe.len() {
            worst = worst
                .max((mean[k] - want_mean[k]).abs())
                .max((var[k] - want_var[k]).abs());
        }
    }

    for mut c in children {
        let _ = c.kill();
        let _ = c.wait();
    }
    let _ = std::fs::remove_dir_all(&dir);

    println!("3-node drill: killed + restarted node 2, worst |Δ| = {worst:.3e}");
    if worst < 1e-8 {
        println!("CLUSTER PARITY OK");
    } else {
        eprintln!("DRILL FAILED: parity {worst:.3e} exceeds 1e-8");
        std::process::exit(1);
    }
}
