//! BTTB/BCCB inference (paper section 5.3): a *non-separable* isotropic
//! kernel on 2-D spatial data, where Kronecker methods do not apply but
//! the block-Toeplitz structure still gives fast MVMs and a BCCB Whittle
//! log-determinant.
//!
//! Run: `cargo run --release --example spatial_2d`

use msgp::data::{gen_stress_2d, smae};
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::kernels::KernelType;

fn main() -> anyhow::Result<()> {
    // Spatial field: cos(r) exp(-r/6) + noise, sampled at 4000 random
    // locations in a 10 x 10 box (no grid structure in the data).
    let n = 4_000;
    let data = gen_stress_2d(n, 0.05, 13);

    // Matern-5/2 isotropic kernel — does NOT factor across dimensions, so
    // K_UU on the 64 x 64 inducing grid is BTTB, not a Kronecker product.
    let kernel = KernelSpec::Iso {
        ktype: KernelType::Matern52,
        log_ell: 1.0f64.ln(),
        log_sf2: 0.0,
        dim: 2,
    };
    let cfg = MsgpConfig { n_per_dim: vec![64, 64], ..Default::default() };
    let mut model = MsgpModel::fit(kernel, 0.05, data, cfg)?;
    println!(
        "fitted BTTB model: n = {}, grid = 64x64 (m = {}), CG iters = {}",
        model.n(),
        model.m(),
        model.last_cg.iters
    );

    // Learn hypers through the BCCB Whittle log-det.
    let trace = model.train(20, 0.1)?;
    println!("LML {:.1} -> {:.1} over 20 Adam steps", trace[0], model.lml());

    let test = gen_stress_2d(1_000, 0.0, 14);
    let mean = model.predict_mean(&test.x);
    let var = model.predict_var(&test.x);
    println!("test SMAE = {:.4}", smae(&mean, &test.y));
    let avg_std: f64 = var.iter().map(|v| v.sqrt()).sum::<f64>() / var.len() as f64;
    println!("mean predictive std = {avg_std:.4}");
    Ok(())
}
