//! The section-6.1 stress test at scale: MSGP marginal-likelihood
//! evaluations on hundreds of thousands of points with m up to 10^5
//! inducing points, demonstrating the near-flat scaling in m that is the
//! headline of Figure 2.
//!
//! Run: `cargo run --release --example stress_1d`

use std::time::Instant;

use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};

fn main() -> anyhow::Result<()> {
    println!("{:>10} {:>10} {:>12} {:>12} {:>8}", "n", "m", "fit_s", "grad_s", "cg");
    for &n in &[10_000usize, 100_000, 300_000] {
        let data = gen_stress_1d(n, 0.05, 21);
        for &m in &[1_000usize, 10_000, 100_000] {
            let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
            let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, m)]);
            let cfg = MsgpConfig { n_per_dim: vec![m], ..Default::default() };
            let t0 = Instant::now();
            let model =
                MsgpModel::fit_with_grid(kernel, 0.01, data.clone(), grid, cfg)?;
            let fit_s = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let g = model.lml_grad();
            let grad_s = t1.elapsed().as_secs_f64();
            println!(
                "{:>10} {:>10} {:>12.3} {:>12.3} {:>8}   lml={:.1}",
                n, m, fit_s, grad_s, model.last_cg.iters, g.lml
            );
        }
    }
    println!("\nNote how the cost moves with n but barely with m — the");
    println!("Kronecker/Toeplitz/circulant structure does the heavy lifting.");
    Ok(())
}
