//! Supervised projections (paper section 5.4 / Figure 5): recover a
//! ground-truth 2-D subspace of a 20-dimensional input space by learning
//! the projection matrix P through the marginal likelihood, jointly with
//! the kernel hyperparameters.
//!
//! Run: `cargo run --release --example projections`

use msgp::data::{gen_projection_data, smae, Dataset};
use msgp::gp::exact::ExactGp;
use msgp::gp::msgp::{MsgpConfig, ProjMsgp};
use msgp::kernels::{KernelType, ProductKernel};

fn main() -> anyhow::Result<()> {
    let (n, n_test, bigd, d) = (2500, 400, 20, 2);
    println!("generating: y ~ GP(k_SE) on x' = P x, P in R^{{{d}x{bigd}}}, n = {n}");
    let kern = ProductKernel::iso(KernelType::SE, d, 1.5, 1.0);
    let pd = gen_projection_data(n + n_test, bigd, d, &kern, 0.05, 3);
    let train = Dataset {
        x: pd.data.x[..n * bigd].to_vec(),
        d: bigd,
        y: pd.data.y[..n].to_vec(),
    };
    let test_x = &pd.data.x[n * bigd..];
    let test_y = &pd.data.y[n..];

    // Learn P on a 50 x 50 inducing grid, from a ridge-informed start
    // (first row = the target's linear trend direction).
    let p0 = ProjMsgp::informed_init(d, &train, 9);
    let cfg = MsgpConfig { n_per_dim: vec![50, 50], n_var_samples: 5, ..Default::default() };
    let mut proj = ProjMsgp::fit(p0, kern.clone(), 0.05, train.clone(), cfg)?;
    println!("initial subspace error: {:.4}", proj.subspace_error(&pd.p_true));
    // Two-phase optimization: noise frozen while P finds the subspace
    // (avoids the explain-as-noise local optimum), then joint.
    for round in 0..10 {
        proj.train_with(30, 0.05, round < 5)?;
        println!(
            "after {:>3} iters: subspace error {:.4}, LML {:.1}, sigma2 {:.4}",
            (round + 1) * 30,
            proj.subspace_error(&pd.p_true),
            proj.model.lml(),
            proj.model.sigma2
        );
    }

    // Compare against GP Full (exact GP on raw 20-D inputs).
    let pred = proj.predict_mean(test_x);
    let smae_proj = smae(&pred, test_y);
    let gp_full = ExactGp::fit(ProductKernel::iso(KernelType::SE, bigd, 2.0, 1.0), 0.05, train)?;
    let smae_full = smae(&gp_full.predict_mean(test_x), test_y);
    println!("test SMAE: GP-Proj (learned P) = {smae_proj:.4}, GP-Full (raw 20-D) = {smae_full:.4}");
    if smae_proj < smae_full {
        println!("learned projection beats the raw high-dimensional GP, as in Figure 5b");
    }
    Ok(())
}
