//! End-to-end serving driver (the DESIGN.md E2E validation): train an
//! MSGP model on a real (synthetic) workload, freeze its O(1)-prediction
//! state, load the AOT-compiled JAX/Pallas artifacts through PJRT, and
//! serve a stream of batched prediction requests through the coordinator,
//! reporting throughput and latency percentiles.
//!
//! Run after `make artifacts`:
//! `cargo run --release --example serving`
//!
//! Without artifacts it degrades gracefully to the native Rust engine
//! (same numerics; the comparison between the two is part of the output).

use std::time::{Duration, Instant};

use msgp::coordinator::{BatcherConfig, EngineSpec, Server, ServingModel};
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::util::Rng;

/// Open-loop pipelined load generator: keeps `window` requests in flight.
fn run_load(server: &std::sync::Arc<Server>, total: usize, window: usize) -> f64 {
    let mut rng = Rng::new(100);
    let t0 = Instant::now();
    let mut inflight: std::collections::VecDeque<
        std::sync::mpsc::Receiver<anyhow::Result<msgp::coordinator::Prediction>>,
    > = std::collections::VecDeque::with_capacity(window);
    for _ in 0..total {
        if inflight.len() >= window {
            let rx = inflight.pop_front().unwrap();
            let p = rx.recv().expect("reply").expect("prediction");
            assert!(p.mean.is_finite() && p.var >= 0.0);
        }
        let x = rng.uniform_in(-10.0, 10.0);
        inflight.push_back(server.submit(vec![x]).expect("submit"));
    }
    for rx in inflight {
        let p = rx.recv().expect("reply").expect("prediction");
        assert!(p.mean.is_finite());
    }
    total as f64 / t0.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    // --- Train (offline phase) ---
    let n = 20_000;
    println!("training MSGP: n = {n}, m = 512 (grid matches the AOT artifacts)...");
    let data = gen_stress_1d(n, 0.05, 11);
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 512)]);
    let cfg = MsgpConfig { n_per_dim: vec![512], ..Default::default() };
    let t0 = Instant::now();
    let mut model = MsgpModel::fit_with_grid(kernel, 0.01, data, grid, cfg)?;
    model.train(10, 0.1)?;
    let serving = ServingModel::from_msgp(&mut model);
    println!(
        "trained + froze serving state in {:.2}s (LML {:.1}, CG iters {})",
        t0.elapsed().as_secs_f64(),
        model.lml(),
        model.last_cg.iters
    );

    // --- Serve (online phase) ---
    let total = 200_000;
    let window = 256; // in-flight requests
    let batch_cfg = BatcherConfig { max_wait: Duration::from_millis(1), max_batch: 256, eager: true };

    // PJRT path (falls back to native if artifacts are missing).
    let art_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let spec = if art_dir.join("manifest.json").exists() {
        println!("serving via PJRT artifacts from {art_dir:?}");
        EngineSpec::Pjrt(art_dir)
    } else {
        println!("no artifacts found; serving via the native engine");
        EngineSpec::Native
    };
    let server = std::sync::Arc::new(Server::start(serving.clone(), spec, batch_cfg.clone()));
    let thr = run_load(&server, total, window);
    println!("-- PJRT/auto backend --");
    println!("throughput: {thr:.0} predictions/s ({window} requests in flight)");
    println!(
        "latency: p50 <= {} us, p99 <= {} us",
        server.metrics.latency_quantile_us(0.5),
        server.metrics.latency_quantile_us(0.99)
    );
    println!("metrics: {}", server.metrics.summary());

    // Native engine for comparison.
    let native = std::sync::Arc::new(Server::start(serving, EngineSpec::Native, batch_cfg));
    let thr_native = run_load(&native, total, window);
    println!("-- native backend --");
    println!("throughput: {thr_native:.0} predictions/s");
    println!("metrics: {}", native.metrics.summary());
    Ok(())
}
