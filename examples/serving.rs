//! End-to-end serving walkthrough: train an MSGP model on a synthetic
//! workload, boot a sharded streaming server behind the real HTTP
//! front door on a loopback port, drive it over actual sockets with
//! the loadgen harness, and read the observability surfaces
//! (`/metrics?format=prom`, `/healthz`, `/shards?verbose=1`, `/trace`)
//! back over the wire.
//!
//! `cargo run --release --example serving`
//!
//! While it runs, the printed `curl` commands work from another shell;
//! set `MSGP_TRACE=1` / `MSGP_SLOW_MS=50` to see spans and slow-request
//! logging. For a long-lived server to poke at, use
//! `cargo run --release --bin loadgen -- --serve`.

use std::sync::Arc;
use std::time::Instant;

use msgp::bench::loadgen::{HttpClient, LoadConfig};
use msgp::coordinator::{BatcherConfig, HttpConfig, HttpServer, Server};
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::shard::{ShardConfig, ShardedTrainer};

fn main() -> anyhow::Result<()> {
    // --- Train (offline phase): a 2-shard streaming trainer. ---
    let n = 20_000;
    let shards = 2;
    println!("training sharded MSGP: n = {n}, m = 512, {shards} shards...");
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 512)]);
    let cfg = ShardConfig {
        shards,
        refresh_every: 8192,
        msgp: MsgpConfig { n_per_dim: vec![512], n_var_samples: 4, ..Default::default() },
        ..Default::default()
    };
    let trainer = ShardedTrainer::start(kernel, 0.01, grid, cfg);
    let data = gen_stress_1d(n, 0.05, 11);
    let t0 = Instant::now();
    trainer.ingest_batch(&data.x, &data.y);
    trainer.flush();
    println!("ingested + refreshed in {:.2}s", t0.elapsed().as_secs_f64());

    // --- Serve (online phase): the HTTP front door on loopback. ---
    let server = Arc::new(Server::start_sharded(trainer, BatcherConfig::default()));
    let http = HttpServer::bind(server, "127.0.0.1:0", HttpConfig::default())?;
    let addr = http.local_addr();
    println!("front door up on http://{addr}; from another shell:");
    println!("  curl -s -X POST http://{addr}/predict -d '{{\"points\": [0.5, 1.5]}}'");
    println!("  curl -s -X POST http://{addr}/ingest -d '{{\"xs\": [2.0], \"ys\": [0.4]}}'");
    println!("  curl -s http://{addr}/healthz");
    println!("  curl -s 'http://{addr}/shards?verbose=1'");
    println!("  curl -s 'http://{addr}/metrics?format=prom' | grep http_");
    println!("  curl -s 'http://{addr}/trace?clear=1' > trace.json   # chrome://tracing");

    // One request by hand, then a short closed-loop load.
    let mut client = HttpClient::new(addr);
    let (status, body) =
        client.request("POST", "/predict", Some(r#"{"points": [0.5, 1.5, 4.0]}"#))?;
    println!("POST /predict -> {status} {body}");

    println!("running a closed-loop load (4 clients, 90% reads)...");
    let report = msgp::bench::loadgen::run(&LoadConfig {
        addr,
        clients: 4,
        requests_per_client: 500,
        ..LoadConfig::default()
    });
    println!("{}", report.summary_line());

    // --- Observe: the wire-level view of what just happened. ---
    let (_, health) = client.request("GET", "/healthz", None)?;
    println!("GET /healthz -> {health}");
    let (_, shards_txt) = client.request("GET", "/shards?verbose=1", None)?;
    print!("GET /shards?verbose=1 ->\n{shards_txt}");
    let (_, prom) = client.request("GET", "/metrics?format=prom", None)?;
    println!("front-door families from /metrics?format=prom:");
    for line in prom.lines().filter(|l| l.starts_with("http_") && !l.contains("_bucket")) {
        println!("  {line}");
    }
    drop(client);
    http.shutdown();
    println!("front door drained and joined; done.");
    Ok(())
}
