//! Online streaming demo: start a coordinator with an *empty* model,
//! stream observations through the `/ingest` route while predictions are
//! being served, and watch the served model sharpen live.
//!
//! `cargo run --release --example streaming`

use msgp::coordinator::{BatcherConfig, EngineSpec, Server};
use msgp::data::{gen_stress_1d, stress_fn};
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::stream::{StreamConfig, StreamTrainer};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 512)]);
    let cfg = StreamConfig {
        msgp: MsgpConfig { n_per_dim: vec![512], n_var_samples: 10, ..Default::default() },
        refresh_every: 2048,
        ..Default::default()
    };
    let trainer = StreamTrainer::new(kernel, 0.01, grid, cfg);
    let server = Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default());

    let probe = 1.5;
    let truth = stress_fn(probe);
    let p0 = server.predict(vec![probe])?;
    println!("prior:       mean {:+.4}  var {:.4}   (truth {truth:+.4})", p0.mean, p0.var);

    // Stream 20k observations in 40 batches; the ingest thread refreshes
    // and swaps the served snapshot every 2048 points.
    let data = gen_stress_1d(20_000, 0.05, 11);
    let bs = 500;
    let t0 = Instant::now();
    for c in 0..data.y.len() / bs {
        let lo = c * bs;
        let hi = lo + bs;
        server.ingest(data.x[lo..hi].to_vec(), data.y[lo..hi].to_vec())?;
        if (c + 1) % 8 == 0 {
            let p = server.predict(vec![probe])?;
            println!(
                "n = {:>6}:  mean {:+.4}  var {:.4}",
                (c + 1) * bs,
                p.mean,
                p.var
            );
        }
    }
    let ingest_wall = t0.elapsed();
    server.flush_stream()?;
    let p1 = server.predict(vec![probe])?;
    println!("final:       mean {:+.4}  var {:.4}   (truth {truth:+.4})", p1.mean, p1.var);
    println!(
        "ingest throughput: {:.0} points/s",
        data.y.len() as f64 / ingest_wall.as_secs_f64()
    );
    println!("metrics: {}", server.metrics.summary());
    server.shutdown();
    Ok(())
}
