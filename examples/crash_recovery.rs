//! Crash/restore drill with a real `SIGKILL`:
//!
//! ```sh
//! cargo run --release --example crash_recovery
//! ```
//!
//! The parent re-executes itself as a victim serving process
//! (`--serve <ckpt-dir>`) that streams observations into an online
//! server, checkpointing every 100 points. Once the victim has at
//! least one valid checkpoint on disk the parent kills it — hard, no
//! graceful shutdown, deliberately racing the atomic checkpoint write.
//! It then reads back whatever survived (a torn final write falls back
//! to the rotated file), restarts a server that restores and replays
//! the statistics, streams the not-yet-durable remainder of the same
//! data, and verifies the served predictions against an uninterrupted
//! in-process trainer to 1e-10. Prints `RECOVERY OK` on success — the
//! CI chaos job greps for it.

use msgp::coordinator::{BatcherConfig, EngineSpec, Server};
use msgp::data::gen_stress_1d;
use msgp::fault::load_newest;
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::stream::{StreamConfig, StreamTrainer};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

const N: usize = 2000;
const BATCH: usize = 100;

fn se_kernel() -> KernelSpec {
    KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0))
}

fn stream_cfg() -> StreamConfig {
    StreamConfig {
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() },
        refresh_every: 1_000_000, // refreshes happen only at restore + final flush
        ..Default::default()
    }
}

fn grid() -> Grid {
    Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)])
}

/// Victim mode: stream batches into an online server, checkpointing on
/// cadence (`MSGP_CKPT_DIR` etc. are set by the parent), until killed.
fn serve_until_killed() {
    let trainer = StreamTrainer::new(se_kernel(), 0.01, grid(), stream_cfg());
    let server = Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default());
    let data = gen_stress_1d(N, 0.05, 77);
    for c in 0..(N / BATCH) {
        let lo = c * BATCH;
        let _ = server.ingest(data.x[lo..lo + BATCH].to_vec(), data.y[lo..lo + BATCH].to_vec());
        // Pace the stream so the parent's kill lands mid-flight.
        std::thread::sleep(Duration::from_millis(25));
    }
    // Stream exhausted before the kill arrived: park (the parent always
    // kills; exiting here would run the graceful-shutdown checkpoint
    // and make the drill trivially easy).
    loop {
        std::thread::sleep(Duration::from_secs(1));
    }
}

fn wait_for_valid_checkpoint(path: &Path) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_secs(30) {
        if load_newest(path).is_some() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    false
}

fn main() {
    let mut args = std::env::args().skip(1);
    if let Some(flag) = args.next() {
        if flag == "--serve" {
            serve_until_killed();
        }
        eprintln!("unknown argument `{flag}` (this binary re-executes itself with --serve)");
        std::process::exit(2);
    }

    let dir: PathBuf =
        std::env::temp_dir().join(format!("msgp-crash-recovery-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create checkpoint dir");
    let exe = std::env::current_exe().expect("current_exe");
    println!("spawning victim: {} --serve (ckpt dir {})", exe.display(), dir.display());
    let mut child = std::process::Command::new(&exe)
        .arg("--serve")
        .env("MSGP_CKPT_DIR", &dir)
        .env("MSGP_CKPT_EVERY_POINTS", "100")
        .env("MSGP_CKPT_EVERY_MS", "60000")
        .spawn()
        .expect("spawn victim");

    let ckpt_path = dir.join("ski.ckpt");
    if !wait_for_valid_checkpoint(&ckpt_path) {
        let _ = child.kill();
        let _ = child.wait();
        eprintln!("RECOVERY FAILED: victim never produced a valid checkpoint");
        std::process::exit(1);
    }
    // Let a few more checkpoint writes land, then kill without warning.
    std::thread::sleep(Duration::from_millis(130));
    child.kill().expect("SIGKILL victim");
    let _ = child.wait();
    println!("victim killed mid-stream");

    // What survived? A torn in-flight write of ski.ckpt is rejected by
    // its checksum and the rotated previous checkpoint loads instead.
    let (durable, from) = match load_newest(&ckpt_path) {
        Some(cf) => cf,
        None => {
            eprintln!("RECOVERY FAILED: no valid checkpoint survived the kill");
            std::process::exit(1);
        }
    };
    let n_durable = durable.skis[0].n();
    println!(
        "durable checkpoint: seq={} n={} ({})",
        durable.seq,
        n_durable,
        from.display()
    );
    assert!(n_durable >= BATCH && n_durable % BATCH == 0, "writes align to batch boundaries");

    // Restart: the server restores the statistics and replays the
    // refresh; the stream source resends everything not yet durable.
    std::env::set_var("MSGP_CKPT_DIR", &dir);
    std::env::set_var("MSGP_CKPT_EVERY_POINTS", "100");
    let trainer = StreamTrainer::new(se_kernel(), 0.01, grid(), stream_cfg());
    let server = Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default());
    assert_eq!(server.metrics.ckpt_restores_total.get(), 1, "restore must be recorded");
    let data = gen_stress_1d(N, 0.05, 77);
    for c in (n_durable / BATCH)..(N / BATCH) {
        let lo = c * BATCH;
        let k = server
            .ingest(data.x[lo..lo + BATCH].to_vec(), data.y[lo..lo + BATCH].to_vec())
            .expect("replay ingest");
        assert_eq!(k, BATCH);
    }
    server.flush_stream().expect("final flush");

    // Uninterrupted reference with the same batch boundaries and the
    // same refresh schedule (cold at n_durable, warm at the end).
    let mut reference = StreamTrainer::new(se_kernel(), 0.01, grid(), stream_cfg());
    reference.ingest_batch(&data.x[..n_durable], &data.y[..n_durable]);
    reference.refresh();
    reference.ingest_batch(&data.x[n_durable..], &data.y[n_durable..]);
    reference.refresh();
    let probe: Vec<f64> = (0..200).map(|i| -10.0 + 0.1 * i as f64).collect();
    let (want_mean, want_var) = reference.serving_model().predict_batch(&probe);

    let mut worst = 0.0f64;
    for (i, &x) in probe.iter().enumerate() {
        let p = server.predict(vec![x]).expect("predict");
        worst = worst.max((p.mean - want_mean[i]).abs()).max((p.var - want_var[i]).abs());
    }
    server.shutdown();
    std::env::remove_var("MSGP_CKPT_DIR");
    std::env::remove_var("MSGP_CKPT_EVERY_POINTS");
    let _ = std::fs::remove_dir_all(&dir);

    println!("restored n={n_durable}, replayed {} points, worst |Δ| = {worst:.3e}", N - n_durable);
    if worst < 1e-10 {
        println!("RECOVERY OK");
    } else {
        eprintln!("RECOVERY FAILED: parity {worst:.3e} exceeds 1e-10");
        std::process::exit(1);
    }
}
